//! TOML-subset parsing and dotted-key overrides for [`FlintConfig`].
//!
//! Supported TOML subset: `[section]` / `[section.sub]` headers, `key =
//! value` with string / integer / float / boolean values, `#` comments.
//! That covers every config file this project ships; exotic TOML (arrays
//! of tables, datetimes, multi-line strings) is intentionally rejected.

use super::{CacheTier, FlintConfig, ShuffleBackend, ShuffleCodec, ShuffleExchange};

/// Apply the contents of a TOML document to `cfg`.
pub fn apply_toml(cfg: &mut FlintConfig, text: &str) -> Result<(), String> {
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        let value = unquote(value.trim());
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        apply_override(cfg, &full_key, &value)
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
    }
    Ok(())
}

fn strip_comment(line: &str) -> &str {
    // Only strip # outside of quotes (our values never contain # anyway,
    // but be careful with quoted strings).
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

macro_rules! parse_to {
    ($field:expr, $value:expr, $key:expr) => {
        $field = $value
            .parse()
            .map_err(|_| format!("bad value `{}` for `{}`", $value, $key))?
    };
}

/// Apply one dotted-key override.
pub fn apply_override(cfg: &mut FlintConfig, key: &str, value: &str) -> Result<(), String> {
    match key {
        "seed" => parse_to!(cfg.seed, value, key),
        "artifacts_dir" => cfg.artifacts_dir = value.to_string(),

        "sim.s3_flint_mbps" => parse_to!(cfg.sim.s3_flint_mbps, value, key),
        "sim.s3_spark_mbps" => parse_to!(cfg.sim.s3_spark_mbps, value, key),
        "sim.s3_first_byte_s" => parse_to!(cfg.sim.s3_first_byte_s, value, key),
        "sim.s3_put_mbps" => parse_to!(cfg.sim.s3_put_mbps, value, key),
        "sim.lambda_cold_start_s" => parse_to!(cfg.sim.lambda_cold_start_s, value, key),
        "sim.lambda_warm_start_s" => parse_to!(cfg.sim.lambda_warm_start_s, value, key),
        "sim.lambda_memory_mb" => parse_to!(cfg.sim.lambda_memory_mb, value, key),
        "sim.lambda_time_limit_s" => parse_to!(cfg.sim.lambda_time_limit_s, value, key),
        "sim.lambda_chain_margin_s" => parse_to!(cfg.sim.lambda_chain_margin_s, value, key),
        "sim.lambda_payload_limit_bytes" => {
            parse_to!(cfg.sim.lambda_payload_limit_bytes, value, key)
        }
        "sim.max_concurrency" => parse_to!(cfg.sim.max_concurrency, value, key),
        "sim.cluster_shuffle_mbps" => parse_to!(cfg.sim.cluster_shuffle_mbps, value, key),
        "sim.sqs_rtt_s" => parse_to!(cfg.sim.sqs_rtt_s, value, key),
        "sim.sqs_mbps" => parse_to!(cfg.sim.sqs_mbps, value, key),
        "sim.sqs_batch_max_msgs" => parse_to!(cfg.sim.sqs_batch_max_msgs, value, key),
        "sim.sqs_batch_max_bytes" => parse_to!(cfg.sim.sqs_batch_max_bytes, value, key),
        "sim.sqs_duplicate_prob" => parse_to!(cfg.sim.sqs_duplicate_prob, value, key),
        "sim.lambda_failure_prob" => parse_to!(cfg.sim.lambda_failure_prob, value, key),
        "sim.compute_scale" => parse_to!(cfg.sim.compute_scale, value, key),
        "sim.pyspark_pipe_per_record_s" => {
            parse_to!(cfg.sim.pyspark_pipe_per_record_s, value, key)
        }
        "sim.scheduler_overhead_per_stage_s" => {
            parse_to!(cfg.sim.scheduler_overhead_per_stage_s, value, key)
        }
        "sim.scheduler_overhead_per_task_s" => {
            parse_to!(cfg.sim.scheduler_overhead_per_task_s, value, key)
        }
        "sim.straggler_prob" => parse_to!(cfg.sim.straggler_prob, value, key),
        "sim.straggler_factor" => parse_to!(cfg.sim.straggler_factor, value, key),
        "sim.straggler_alpha" => parse_to!(cfg.sim.straggler_alpha, value, key),
        "sim.straggler_containers" => parse_to!(cfg.sim.straggler_containers, value, key),

        "pricing.lambda_gb_s" => parse_to!(cfg.pricing.lambda_gb_s, value, key),
        "pricing.lambda_per_request" => parse_to!(cfg.pricing.lambda_per_request, value, key),
        "pricing.sqs_per_million_requests" => {
            parse_to!(cfg.pricing.sqs_per_million_requests, value, key)
        }
        "pricing.s3_get_per_1000" => parse_to!(cfg.pricing.s3_get_per_1000, value, key),
        "pricing.s3_put_per_1000" => parse_to!(cfg.pricing.s3_put_per_1000, value, key),
        "pricing.cluster_per_hour" => parse_to!(cfg.pricing.cluster_per_hour, value, key),

        "flint.input_split_bytes" => parse_to!(cfg.flint.input_split_bytes, value, key),
        "flint.default_shuffle_partitions" => {
            parse_to!(cfg.flint.default_shuffle_partitions, value, key)
        }
        "flint.shuffle_buffer_bytes" => parse_to!(cfg.flint.shuffle_buffer_bytes, value, key),
        "flint.max_task_retries" => parse_to!(cfg.flint.max_task_retries, value, key),
        // The dotted spelling joins the `flint.shuffle.*` family; the
        // flat legacy key keeps working.
        "flint.shuffle_backend" | "flint.shuffle.backend" => {
            cfg.flint.shuffle_backend = value.parse::<ShuffleBackend>()?
        }
        "flint.shuffle.codec" => cfg.flint.shuffle_codec = value.parse::<ShuffleCodec>()?,
        "flint.shuffle.exchange" => {
            cfg.flint.shuffle_exchange = value.parse::<ShuffleExchange>()?
        }
        "flint.shuffle.tree_fanout" => {
            // A merge level needs at least two groups on a side to be a
            // tree at all; 0/1 would also divide-by-zero the grouping.
            let n: usize = value
                .parse()
                .map_err(|_| format!("bad value `{value}` for `{key}`"))?;
            if n < 2 {
                return Err(format!(
                    "bad value `{value}` for `{key}` (tree fan-out must be ≥ 2)"
                ));
            }
            cfg.flint.tree_fanout = n;
        }
        "flint.scan.prune" => parse_to!(cfg.flint.scan_prune, value, key),
        "flint.scheduler" => {
            cfg.flint.scheduler = value.parse::<crate::simtime::ScheduleMode>()?
        }
        "flint.speculation" => {
            cfg.flint.speculation.enabled = match value {
                "on" | "true" => true,
                "off" | "false" => false,
                other => {
                    return Err(format!(
                        "bad value `{other}` for `flint.speculation` (want on|off)"
                    ))
                }
            }
        }
        "flint.speculation.multiplier" => {
            parse_to!(cfg.flint.speculation.multiplier, value, key)
        }
        "flint.speculation.quantile" => {
            parse_to!(cfg.flint.speculation.quantile, value, key)
        }
        "flint.service.policy" => {
            cfg.flint.service.policy = value.parse::<crate::simtime::ServicePolicy>()?
        }
        "flint.service.max_queued" => {
            // 0 would make every concurrent submission a rejection;
            // callers wanting no service should leave the knobs unset.
            let n: usize = value
                .parse()
                .map_err(|_| format!("bad value `{value}` for `{key}`"))?;
            if n == 0 {
                return Err(format!(
                    "bad value `{value}` for `{key}` (max queued must be positive)"
                ));
            }
            cfg.flint.service.max_queued = n;
        }
        k if k.starts_with("flint.service.weight.") => {
            let tenant = &k["flint.service.weight.".len()..];
            if tenant.is_empty() {
                return Err(format!("unknown config key `{k}` (missing tenant name)"));
            }
            let w: f64 = value
                .parse()
                .map_err(|_| format!("bad value `{value}` for `{k}`"))?;
            // Fair-share divides held slots by this; zero, negative, and
            // non-finite weights would all break the arbitration math.
            if !(w.is_finite() && w > 0.0) {
                return Err(format!(
                    "bad value `{value}` for `{k}` (weight must be positive and finite)"
                ));
            }
            cfg.flint.service.weights.insert(tenant.to_string(), w);
        }
        k if k.starts_with("flint.service.max_slots.") => {
            let tenant = &k["flint.service.max_slots.".len()..];
            if tenant.is_empty() {
                return Err(format!("unknown config key `{k}` (missing tenant name)"));
            }
            let n: usize = value
                .parse()
                .map_err(|_| format!("bad value `{value}` for `{k}`"))?;
            // A zero quota would deadlock the tenant's queries: admitted
            // but never able to claim a slot.
            if n == 0 {
                return Err(format!(
                    "bad value `{value}` for `{k}` (max slots must be positive)"
                ));
            }
            cfg.flint.service.max_slots.insert(tenant.to_string(), n);
        }
        "flint.sql.optimizer" => {
            cfg.flint.sql.optimizer = match value {
                "on" | "true" => true,
                "off" | "false" => false,
                other => {
                    return Err(format!(
                        "bad value `{other}` for `flint.sql.optimizer` (want on|off)"
                    ))
                }
            }
        }
        "flint.sql.broadcast_threshold_bytes" => {
            // u64, so any non-negative integer; 0 is meaningful (force
            // shuffle joins — the Q6J plan shape).
            parse_to!(cfg.flint.sql.broadcast_threshold_bytes, value, key)
        }
        "flint.cache.capacity_bytes" => {
            // u64, so any non-negative integer; 0 is meaningful (cache
            // off — `.cache()` markers stay transparent).
            parse_to!(cfg.flint.cache.capacity_bytes, value, key)
        }
        "flint.cache.tier" => cfg.flint.cache.tier = value.parse::<CacheTier>()?,
        "flint.lambda.keepalive_s" => {
            let s: f64 = value
                .parse()
                .map_err(|_| format!("bad value `{value}` for `{key}`"))?;
            // 0 = never expire (the pre-keepalive pool model); negative
            // or non-finite windows have no meaning on the clock.
            if !(s.is_finite() && s >= 0.0) {
                return Err(format!(
                    "bad value `{value}` for `{key}` (keep-alive must be ≥ 0 and finite)"
                ));
            }
            cfg.flint.lambda_keepalive_s = s;
        }
        "flint.dedup_enabled" => parse_to!(cfg.flint.dedup_enabled, value, key),
        "flint.batch_rows" => {
            // `ColumnBatch::with_capacity` requires a positive capacity;
            // reject zero here so misconfiguration fails at parse time
            // with the offending key, not mid-query via an assert.
            let rows: usize = value
                .parse()
                .map_err(|_| format!("bad value `{value}` for `{key}`"))?;
            if rows == 0 {
                return Err(format!(
                    "bad value `{value}` for `{key}` (batch rows must be positive)"
                ));
            }
            cfg.flint.batch_rows = rows;
        }
        "flint.use_pjrt" => parse_to!(cfg.flint.use_pjrt, value, key),

        "cluster.workers" => parse_to!(cfg.cluster.workers, value, key),
        "cluster.cores" => parse_to!(cfg.cluster.cores, value, key),
        "cluster.startup_s" => parse_to!(cfg.cluster.startup_s, value, key),

        "data.trips" => parse_to!(cfg.data.trips, value, key),
        "data.object_bytes" => parse_to!(cfg.data.object_bytes, value, key),
        "data.paper_total_bytes" => parse_to!(cfg.data.paper_total_bytes, value, key),
        "data.paper_total_trips" => parse_to!(cfg.data.paper_total_trips, value, key),

        other => return Err(format!("unknown config key `{other}`")),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_sections_and_values() {
        let mut cfg = FlintConfig::default();
        apply_toml(
            &mut cfg,
            r#"
            # a comment
            seed = 99

            [sim]
            max_concurrency = 40   # inline comment
            s3_flint_mbps = 92.5

            [flint]
            shuffle_backend = "s3"
            dedup_enabled = false

            [data]
            trips = 250000
            "#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.sim.max_concurrency, 40);
        assert_eq!(cfg.sim.s3_flint_mbps, 92.5);
        assert_eq!(cfg.flint.shuffle_backend, ShuffleBackend::S3);
        assert!(!cfg.flint.dedup_enabled);
        assert_eq!(cfg.data.trips, 250_000);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let mut cfg = FlintConfig::default();
        let err = apply_toml(&mut cfg, "[sim]\nbogus_key = 1\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("bogus_key"), "{err}");
    }

    #[test]
    fn bad_value_reports_key() {
        let mut cfg = FlintConfig::default();
        let err = apply_override(&mut cfg, "sim.max_concurrency", "many").unwrap_err();
        assert!(err.contains("sim.max_concurrency"), "{err}");
    }

    #[test]
    fn quoted_strings_unquoted() {
        let mut cfg = FlintConfig::default();
        apply_toml(&mut cfg, "artifacts_dir = \"my/arts\"\n").unwrap();
        assert_eq!(cfg.artifacts_dir, "my/arts");
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let mut cfg = FlintConfig::default();
        apply_toml(&mut cfg, "artifacts_dir = \"a#b\"\n").unwrap();
        assert_eq!(cfg.artifacts_dir, "a#b");
    }
}
