//! Configuration for the whole system: simulation parameters (service
//! latency/throughput models, Lambda limits), pricing tables, engine
//! knobs, and data-generation settings.
//!
//! Config is layered: built-in defaults (calibrated to the paper's 2018
//! AWS environment, DESIGN.md §5) → optional TOML file → CLI `--set
//! key=value` overrides. The TOML reader is a self-contained subset
//! parser (`parse.rs`); `serde`/`toml` are unavailable offline.

pub mod parse;

use crate::simtime::{ScheduleMode, ServicePolicy};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Service-model parameters. All durations in seconds, rates in MB/s.
#[derive(Debug, Clone, PartialEq)]
pub struct SimParams {
    /// Per-stream S3 read throughput for Flint's executors (the paper's
    /// boto finding: "much better throughput than the library Spark uses").
    pub s3_flint_mbps: f64,
    /// Per-stream S3 read throughput for the Spark cluster's Hadoop S3A
    /// connector.
    pub s3_spark_mbps: f64,
    /// S3 GET first-byte latency.
    pub s3_first_byte_s: f64,
    /// S3 PUT throughput per stream.
    pub s3_put_mbps: f64,
    /// Lambda cold-start latency (Python runtime; the paper's motivation
    /// for Python executors over Java).
    pub lambda_cold_start_s: f64,
    /// Warm invocation dispatch latency.
    pub lambda_warm_start_s: f64,
    /// Lambda memory allocation (paper: maximum, 3008 MB).
    pub lambda_memory_mb: u64,
    /// Lambda execution duration cap (paper-era: 300 s).
    pub lambda_time_limit_s: f64,
    /// Safety margin before the cap at which executors checkpoint & chain.
    pub lambda_chain_margin_s: f64,
    /// Invocation request payload cap (6 MB).
    pub lambda_payload_limit_bytes: u64,
    /// Maximum concurrent invocations (paper: 80, matching 80 vCores).
    pub max_concurrency: usize,
    /// Cluster-internal shuffle bandwidth (Spark's local-disk + network
    /// path; the baseline's analogue of Flint's SQS hop).
    pub cluster_shuffle_mbps: f64,
    /// SQS request round-trip contribution per API call.
    pub sqs_rtt_s: f64,
    /// SQS bandwidth while streaming message bodies.
    pub sqs_mbps: f64,
    /// Max messages per SQS batch API call.
    pub sqs_batch_max_msgs: usize,
    /// Max total payload per batch call (256 KB).
    pub sqs_batch_max_bytes: usize,
    /// Probability a delivered message is duplicated (at-least-once).
    pub sqs_duplicate_prob: f64,
    /// Probability an invocation crashes before completing (retry path).
    pub lambda_failure_prob: f64,
    /// Multiplier applied to *measured* compute time, to model slower/
    /// faster hardware than this host (1.0 = as measured).
    pub compute_scale: f64,
    /// Per-record JVM→Python pipe overhead for the PySpark baseline.
    pub pyspark_pipe_per_record_s: f64,
    /// Driver-side overhead per stage (task serialization, bookkeeping).
    pub scheduler_overhead_per_stage_s: f64,
    /// Per-task scheduler-side serialization/launch overhead.
    pub scheduler_overhead_per_task_s: f64,
    /// Probability a task attempt lands on a straggling container
    /// (heavy-tailed slowdown injection; 0 = off). Drawn deterministically
    /// from `(seed, stage, task, attempt)`, so the same attempts straggle
    /// across runs and an attempt's backup rolls independently.
    pub straggler_prob: f64,
    /// Minimum slowdown factor of a straggling attempt (the Pareto
    /// distribution's scale: every straggler is at least this slow).
    pub straggler_factor: f64,
    /// Pareto tail exponent for straggler slowdowns (smaller = heavier
    /// tail). Factors are capped at 25x.
    pub straggler_alpha: f64,
    /// Container-affinity straggler mode: when > 0, attempts land on one
    /// of this many simulated containers (hashed from `(seed, stage,
    /// task, attempt)`) and a *container*, not an attempt, is the unit
    /// that straggles — every attempt placed on a slow container is slow.
    /// This is what makes straggler *prediction* from per-container
    /// history possible. 0 (default) keeps the per-attempt i.i.d. model.
    pub straggler_containers: usize,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            // Effective per-stream S3 throughput, *including* client-side
            // overhead, calibrated from the paper's Q0 (DESIGN.md §5):
            // Flint/boto: 215 GB / (80 × 101 s) ≈ 27.5 MB/s;
            // Spark/Hadoop-S3A: 215 GB / (80 × 188 s) ≈ 14.6 MB/s.
            s3_flint_mbps: 27.5,
            s3_spark_mbps: 14.6,
            s3_first_byte_s: 0.020,
            s3_put_mbps: 60.0,
            lambda_cold_start_s: 0.250,
            lambda_warm_start_s: 0.015,
            lambda_memory_mb: 3008,
            lambda_time_limit_s: 300.0,
            lambda_chain_margin_s: 10.0,
            lambda_payload_limit_bytes: 6 * 1024 * 1024,
            max_concurrency: 80,
            cluster_shuffle_mbps: 300.0,
            sqs_rtt_s: 0.0015,
            sqs_mbps: 80.0,
            sqs_batch_max_msgs: 10,
            sqs_batch_max_bytes: 256 * 1024,
            sqs_duplicate_prob: 0.0,
            lambda_failure_prob: 0.0,
            compute_scale: 1.0,
            pyspark_pipe_per_record_s: 1.2e-6,
            scheduler_overhead_per_stage_s: 0.35,
            scheduler_overhead_per_task_s: 0.002,
            straggler_prob: 0.0,
            straggler_factor: 6.0,
            straggler_alpha: 2.0,
            straggler_containers: 0,
        }
    }
}

/// AWS pricing circa the paper (2018, us-east-1), USD.
#[derive(Debug, Clone, PartialEq)]
pub struct Pricing {
    /// Lambda: $ per GB-second.
    pub lambda_gb_s: f64,
    /// Lambda: $ per request.
    pub lambda_per_request: f64,
    /// SQS: $ per million requests (each 64 KB chunk is one request).
    pub sqs_per_million_requests: f64,
    /// S3: $ per 1000 GET requests.
    pub s3_get_per_1000: f64,
    /// S3: $ per 1000 PUT requests.
    pub s3_put_per_1000: f64,
    /// Cluster: $ per hour for the whole 11 × m4.2xlarge Databricks
    /// deployment (calibrated from Table I: 188 s ↔ $0.37).
    pub cluster_per_hour: f64,
}

impl Default for Pricing {
    fn default() -> Self {
        Pricing {
            lambda_gb_s: 0.00001667,
            lambda_per_request: 0.0000002,
            sqs_per_million_requests: 0.40,
            s3_get_per_1000: 0.0004,
            s3_put_per_1000: 0.005,
            cluster_per_hour: 7.08,
        }
    }
}

/// Speculative-execution (backup task) knobs, mirroring Spark's
/// `spark.speculation.*` family. When enabled, the scheduler watches the
/// event clock's tail signal: once `quantile` of a stage's tasks have
/// finished, any task still running past `multiplier` × the median
/// completed span gets a backup attempt; the first attempt to commit
/// wins and the loser is cancelled (but still billed — Lambda has no
/// mid-flight cancellation).
#[derive(Debug, Clone, PartialEq)]
pub struct SpeculationParams {
    /// `flint.speculation = on|off`. Off (the default, like Spark)
    /// reproduces non-speculative schedules byte-identically.
    pub enabled: bool,
    /// A task is speculatable once it has run `multiplier` × the median
    /// span of its stage's completed tasks (`flint.speculation.multiplier`).
    pub multiplier: f64,
    /// Fraction of a stage's tasks that must complete before the median
    /// is trusted (`flint.speculation.quantile`); 1.0 disables the signal.
    pub quantile: f64,
}

impl Default for SpeculationParams {
    fn default() -> Self {
        SpeculationParams { enabled: false, multiplier: 1.5, quantile: 0.75 }
    }
}

/// Multi-tenant service knobs (`flint.service.*`), read by
/// `exec::service::FlintService`. A plain `FlintContext` never consults
/// these, so single-query runs are byte-identical whatever they hold.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceParams {
    /// Slot arbitration between concurrent queries
    /// (`flint.service.policy = fifo|fair|weighted`).
    pub policy: ServicePolicy,
    /// Admission control: queries may wait in a bounded queue while the
    /// pool is saturated; a submission past this depth is rejected with a
    /// typed error (`flint.service.max_queued`, must be ≥ 1).
    pub max_queued: usize,
    /// Per-tenant fair-share weights (`flint.service.weight.<tenant>`,
    /// each must be positive and finite). Tenants absent here weigh 1.0.
    pub weights: BTreeMap<String, f64>,
    /// Per-tenant concurrency quotas (`flint.service.max_slots.<tenant>`,
    /// each must be ≥ 1): a hard cap on the slots a tenant's queries may
    /// hold at once, layered on top of the fair-share weights. Tenants
    /// absent here are uncapped. A quota caps *primaries and backups
    /// combined*, so a capped tenant cannot speculate its way past it.
    pub max_slots: BTreeMap<String, usize>,
}

impl Default for ServiceParams {
    fn default() -> Self {
        ServiceParams {
            policy: ServicePolicy::Fair,
            max_queued: 64,
            weights: BTreeMap::new(),
            max_slots: BTreeMap::new(),
        }
    }
}

impl ServiceParams {
    /// Effective weight of a tenant (1.0 unless configured).
    pub fn weight_of(&self, tenant: &str) -> f64 {
        self.weights.get(tenant).copied().unwrap_or(1.0)
    }

    /// Concurrency quota of a tenant (`None` = uncapped).
    pub fn quota_of(&self, tenant: &str) -> Option<usize> {
        self.max_slots.get(tenant).copied()
    }
}

/// SQL frontend knobs (`flint.sql.*`), read by `sql::compile`.
#[derive(Debug, Clone, PartialEq)]
pub struct SqlParams {
    /// `flint.sql.optimizer = on|off`. Off lowers the analyzed plan
    /// verbatim: no predicate/projection pushdown, no constant folding,
    /// shuffle joins and default partition counts everywhere — the
    /// ablation baseline for bench A9.
    pub optimizer: bool,
    /// Broadcast-join eligibility cap: a build side estimated larger
    /// than this many bytes always shuffles
    /// (`flint.sql.broadcast_threshold_bytes`; 0 forces every join
    /// through the shuffle).
    pub broadcast_threshold_bytes: u64,
}

impl Default for SqlParams {
    fn default() -> Self {
        SqlParams { optimizer: true, broadcast_threshold_bytes: 64 * 1024 * 1024 }
    }
}

/// Lineage-cache knobs (`flint.cache.*`), read by the session layer's
/// cache registry (`exec::cache`). Capacity 0 — the default — disables
/// the cache entirely: `Rdd::cache()` markers stay transparent and every
/// plan, report, and metric is byte-identical to a build without them.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheParams {
    /// Total bytes the registry may hold across both tiers before LRU
    /// eviction (`flint.cache.capacity_bytes`; 0 = cache off).
    pub capacity_bytes: u64,
    /// Which storage tiers admission may use
    /// (`flint.cache.tier = memory|s3|both`). The effective tier of an
    /// entry is this ∩ the `persist(StorageLevel)` the lineage asked for.
    pub tier: CacheTier,
}

impl Default for CacheParams {
    fn default() -> Self {
        CacheParams { capacity_bytes: 0, tier: CacheTier::Both }
    }
}

/// Storage tiers the cache registry may admit into (`flint.cache.tier`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheTier {
    /// Warm-container memory only (entries die with the pool).
    Memory,
    /// Committed S3 objects only.
    S3,
    /// S3 always; memory additionally when the cost model says a
    /// partition is worth pinning.
    Both,
}

impl std::str::FromStr for CacheTier {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "memory" => Ok(CacheTier::Memory),
            "s3" => Ok(CacheTier::S3),
            "both" => Ok(CacheTier::Both),
            other => Err(format!("unknown cache tier `{other}` (want memory|s3|both)")),
        }
    }
}

impl CacheTier {
    pub fn name(&self) -> &'static str {
        match self {
            CacheTier::Memory => "memory",
            CacheTier::S3 => "s3",
            CacheTier::Both => "both",
        }
    }
}

/// Flint engine knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct FlintParams {
    /// Target split size for S3 input partitions (bytes).
    pub input_split_bytes: u64,
    /// Default number of reduce partitions when a query doesn't specify.
    pub default_shuffle_partitions: usize,
    /// Executor in-memory shuffle buffer before flushing to SQS (bytes).
    pub shuffle_buffer_bytes: usize,
    /// Max task retries before the query fails.
    pub max_task_retries: u32,
    /// Shuffle transport: "sqs" (the paper), "s3" (the Qubole ablation),
    /// or "auto" — pick per DAG edge from estimated partition size ×
    /// fan-out using the calibrated cost model (payload-inline for tiny
    /// edges, SQS mid-range, S3 for wide fan-outs).
    pub shuffle_backend: ShuffleBackend,
    /// Exchange topology for the S3 shuffle (`flint.shuffle.exchange`):
    /// "direct" writes one object per (producer, consumer-partition) edge
    /// — O(n²) requests at n×n fan-out — while "tree" inserts a merge
    /// level above `tree_fanout` (Lambada's multi-level exchange):
    /// producers write one combined object per consumer *group*, a merge
    /// level re-partitions, and consumers read O(n·√n)-ish objects.
    pub shuffle_exchange: ShuffleExchange,
    /// Fan-out (max(producers, partitions)) above which `exchange = tree`
    /// actually inserts the merge level; below it even tree-mode edges
    /// run direct, since the extra level only pays for itself once
    /// per-edge request counts dominate (`flint.shuffle.tree_fanout`,
    /// must be ≥ 2).
    pub tree_fanout: usize,
    /// Shuffle wire codec: "columnar" (the default — sorted runs of
    /// kernel partials ride as delta-encoded column chunks, dyn pairs as
    /// front-coded groups) or "rows" (one record per wire entry, the
    /// pre-columnar format). Results are byte-identical either way; only
    /// the transported bytes differ.
    pub shuffle_codec: ShuffleCodec,
    /// Statistics-based scan pruning: skip fetching input splits whose
    /// manifest min/max day-month statistics fall entirely outside the
    /// query's predicate range (`flint.scan.prune`, default on).
    pub scan_prune: bool,
    /// Stage-overlap policy for the virtual clock: "pipelined" (the
    /// default since the Table I re-baseline: §III-A SQS semantics,
    /// reducers long-poll while mappers flush) or "barrier" (serial
    /// stages, the Σ-makespan model — the exact-paper-reproduction mode
    /// whose numbers match the original Table I baseline). SQS-only —
    /// the S3 backend's list-then-get shuffle cannot overlap, so the
    /// engine forces barrier there.
    pub scheduler: ScheduleMode,
    /// Speculative re-execution of stragglers (`flint.speculation.*`).
    pub speculation: SpeculationParams,
    /// Multi-tenant service layer (`flint.service.*`).
    pub service: ServiceParams,
    /// SQL frontend (`flint.sql.*`).
    pub sql: SqlParams,
    /// Lineage cache (`flint.cache.*`).
    pub cache: CacheParams,
    /// Warm-container keep-alive window (`flint.lambda.keepalive_s`):
    /// how long a returned container stays warm on the virtual clock
    /// before its next draw is a cold start again. 0 (the default)
    /// keeps containers warm forever once touched — the pre-keepalive
    /// pool model, byte-identical to builds without this knob.
    pub lambda_keepalive_s: f64,
    /// Enable sequence-id dedup of SQS messages (§VI).
    pub dedup_enabled: bool,
    /// Rows per columnar batch handed to the PJRT kernels.
    pub batch_rows: usize,
    /// Use the AOT HLO artifacts via PJRT when available (fall back to the
    /// native kernels when artifacts are absent, e.g. unit tests).
    pub use_pjrt: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShuffleBackend {
    Sqs,
    S3,
    /// Per-edge auto-selection from the calibrated cost model.
    Auto,
}

impl std::str::FromStr for ShuffleBackend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sqs" => Ok(ShuffleBackend::Sqs),
            "s3" => Ok(ShuffleBackend::S3),
            "auto" => Ok(ShuffleBackend::Auto),
            other => Err(format!("unknown shuffle backend `{other}` (want sqs|s3|auto)")),
        }
    }
}

/// Exchange topology for S3-backed shuffles (`flint.shuffle.exchange`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShuffleExchange {
    /// One object per (producer, consumer-partition) edge.
    Direct,
    /// Multi-level: combined per-group intermediates + a merge level.
    Tree,
}

impl std::str::FromStr for ShuffleExchange {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "direct" => Ok(ShuffleExchange::Direct),
            "tree" => Ok(ShuffleExchange::Tree),
            other => Err(format!("unknown shuffle exchange `{other}` (want direct|tree)")),
        }
    }
}

/// Wire format for shuffle records (`flint.shuffle.codec`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShuffleCodec {
    Rows,
    Columnar,
}

impl std::str::FromStr for ShuffleCodec {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rows" => Ok(ShuffleCodec::Rows),
            "columnar" => Ok(ShuffleCodec::Columnar),
            other => Err(format!("unknown shuffle codec `{other}` (want rows|columnar)")),
        }
    }
}

impl Default for FlintParams {
    fn default() -> Self {
        FlintParams {
            input_split_bytes: 64 * 1024 * 1024,
            default_shuffle_partitions: 30,
            shuffle_buffer_bytes: 48 * 1024 * 1024,
            max_task_retries: 3,
            shuffle_backend: ShuffleBackend::Sqs,
            shuffle_exchange: ShuffleExchange::Direct,
            tree_fanout: 64,
            shuffle_codec: ShuffleCodec::Columnar,
            scan_prune: true,
            scheduler: ScheduleMode::Pipelined,
            speculation: SpeculationParams::default(),
            service: ServiceParams::default(),
            sql: SqlParams::default(),
            cache: CacheParams::default(),
            lambda_keepalive_s: 0.0,
            dedup_enabled: true,
            batch_rows: 8192,
            use_pjrt: true,
        }
    }
}

/// Spark-cluster baseline parameters (11 × m4.2xlarge, 80 vCores).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterParams {
    pub workers: usize,
    pub cores: usize,
    /// Cluster startup time — reported but excluded from latency, exactly
    /// as the paper does ("around five minutes").
    pub startup_s: f64,
}

impl Default for ClusterParams {
    fn default() -> Self {
        ClusterParams { workers: 10, cores: 80, startup_s: 300.0 }
    }
}

/// Data-generation parameters for the synthetic TLC dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct DataParams {
    /// Number of trips to generate for measured-mode runs.
    pub trips: u64,
    /// Object size per generated S3 object (bytes).
    pub object_bytes: u64,
    /// Paper-scale totals used by `--mode paper` extrapolation.
    pub paper_total_bytes: u64,
    pub paper_total_trips: u64,
}

impl Default for DataParams {
    fn default() -> Self {
        DataParams {
            trips: 1_000_000,
            object_bytes: 32 * 1024 * 1024,
            paper_total_bytes: 215 * 1024 * 1024 * 1024,
            paper_total_trips: 1_300_000_000,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FlintConfig {
    pub seed: u64,
    pub sim: SimParams,
    pub pricing: Pricing,
    pub flint: FlintParams,
    pub cluster: ClusterParams,
    pub data: DataParams,
    /// Directory containing the AOT HLO artifacts.
    pub artifacts_dir: String,
}

impl FlintConfig {
    /// Defaults plus a fixed seed.
    pub fn with_seed(seed: u64) -> FlintConfig {
        FlintConfig { seed, ..Default::default() }
    }

    /// A configuration tuned for fast unit tests: tiny splits/buffers so
    /// small datasets still exercise multi-task, multi-flush paths; PJRT
    /// off by default (tests that want it opt in).
    pub fn for_tests() -> FlintConfig {
        let mut c = FlintConfig::with_seed(1234);
        c.flint.input_split_bytes = 64 * 1024;
        c.flint.shuffle_buffer_bytes = 64 * 1024;
        c.flint.batch_rows = 256;
        c.flint.use_pjrt = false;
        c.data.trips = 5_000;
        c.data.object_bytes = 256 * 1024;
        c.sim.max_concurrency = 8;
        c.artifacts_dir = "artifacts".into();
        c
    }

    /// Apply a `key=value` override (dotted keys, e.g.
    /// `sim.max_concurrency=160`). Returns an error naming the key if it
    /// doesn't exist or the value doesn't parse.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        parse::apply_override(self, key, value)
    }

    /// Load from a TOML file then apply overrides.
    pub fn load(path: &str, overrides: &[(String, String)]) -> Result<FlintConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let mut cfg = FlintConfig::default();
        parse::apply_toml(&mut cfg, &text)?;
        for (k, v) in overrides {
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }

    /// JSON dump (for reports / `flint config --print`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("seed", self.seed)
            .set("artifacts_dir", self.artifacts_dir.as_str())
            .set(
                "sim",
                Json::obj()
                    .set("s3_flint_mbps", self.sim.s3_flint_mbps)
                    .set("s3_spark_mbps", self.sim.s3_spark_mbps)
                    .set("s3_first_byte_s", self.sim.s3_first_byte_s)
                    .set("lambda_cold_start_s", self.sim.lambda_cold_start_s)
                    .set("lambda_warm_start_s", self.sim.lambda_warm_start_s)
                    .set("lambda_memory_mb", self.sim.lambda_memory_mb)
                    .set("lambda_time_limit_s", self.sim.lambda_time_limit_s)
                    .set("max_concurrency", self.sim.max_concurrency)
                    .set("sqs_rtt_s", self.sim.sqs_rtt_s)
                    .set("sqs_duplicate_prob", self.sim.sqs_duplicate_prob)
                    .set("lambda_failure_prob", self.sim.lambda_failure_prob)
                    .set("compute_scale", self.sim.compute_scale)
                    .set("straggler_containers", self.sim.straggler_containers),
            )
            .set(
                "flint",
                Json::obj()
                    .set("input_split_bytes", self.flint.input_split_bytes)
                    .set("default_shuffle_partitions", self.flint.default_shuffle_partitions)
                    .set("shuffle_buffer_bytes", self.flint.shuffle_buffer_bytes)
                    .set(
                        "shuffle_backend",
                        match self.flint.shuffle_backend {
                            ShuffleBackend::Sqs => "sqs",
                            ShuffleBackend::S3 => "s3",
                            ShuffleBackend::Auto => "auto",
                        },
                    )
                    .set(
                        "shuffle_exchange",
                        match self.flint.shuffle_exchange {
                            ShuffleExchange::Direct => "direct",
                            ShuffleExchange::Tree => "tree",
                        },
                    )
                    .set("tree_fanout", self.flint.tree_fanout)
                    .set(
                        "shuffle_codec",
                        match self.flint.shuffle_codec {
                            ShuffleCodec::Rows => "rows",
                            ShuffleCodec::Columnar => "columnar",
                        },
                    )
                    .set("scan_prune", self.flint.scan_prune)
                    .set("scheduler", self.flint.scheduler.name())
                    .set(
                        "speculation",
                        Json::obj()
                            .set("enabled", self.flint.speculation.enabled)
                            .set("multiplier", self.flint.speculation.multiplier)
                            .set("quantile", self.flint.speculation.quantile),
                    )
                    .set(
                        "service",
                        Json::obj()
                            .set("policy", self.flint.service.policy.name())
                            .set("max_queued", self.flint.service.max_queued)
                            .set("weights", {
                                let mut w = Json::obj();
                                for (tenant, weight) in &self.flint.service.weights {
                                    w = w.set(tenant.as_str(), *weight);
                                }
                                w
                            })
                            .set("max_slots", {
                                let mut q = Json::obj();
                                for (tenant, slots) in &self.flint.service.max_slots {
                                    q = q.set(tenant.as_str(), *slots);
                                }
                                q
                            }),
                    )
                    .set(
                        "sql",
                        Json::obj()
                            .set("optimizer", self.flint.sql.optimizer)
                            .set(
                                "broadcast_threshold_bytes",
                                self.flint.sql.broadcast_threshold_bytes,
                            ),
                    )
                    .set(
                        "cache",
                        Json::obj()
                            .set("capacity_bytes", self.flint.cache.capacity_bytes)
                            .set("tier", self.flint.cache.tier.name()),
                    )
                    .set("lambda_keepalive_s", self.flint.lambda_keepalive_s)
                    .set("dedup_enabled", self.flint.dedup_enabled)
                    .set("batch_rows", self.flint.batch_rows)
                    .set("use_pjrt", self.flint.use_pjrt),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_constants() {
        let c = FlintConfig::default();
        assert_eq!(c.sim.lambda_memory_mb, 3008);
        assert_eq!(c.sim.lambda_time_limit_s, 300.0);
        assert_eq!(c.sim.lambda_payload_limit_bytes, 6 * 1024 * 1024);
        assert_eq!(c.sim.max_concurrency, 80);
        assert_eq!(c.cluster.cores, 80);
        assert_eq!(c.cluster.workers, 10);
        assert_eq!(c.flint.default_shuffle_partitions, 30); // Q1's reduceByKey(add, 30)
    }

    #[test]
    fn override_roundtrip() {
        let mut c = FlintConfig::default();
        c.set("sim.max_concurrency", "160").unwrap();
        assert_eq!(c.sim.max_concurrency, 160);
        c.set("flint.shuffle_backend", "s3").unwrap();
        assert_eq!(c.flint.shuffle_backend, ShuffleBackend::S3);
        c.set("flint.shuffle_backend", "auto").unwrap();
        assert_eq!(c.flint.shuffle_backend, ShuffleBackend::Auto);
        assert!(c.set("flint.shuffle_backend", "carrier-pigeon").is_err());
        assert_eq!(
            c.flint.scheduler,
            ScheduleMode::Pipelined,
            "pipelined is the default since the Table I re-baseline"
        );
        c.set("flint.scheduler", "barrier").unwrap();
        assert_eq!(c.flint.scheduler, ScheduleMode::Barrier);
        assert!(c.set("flint.scheduler", "bogus").is_err());
        assert!(c.set("sim.nonexistent", "1").is_err());
        assert!(c.set("sim.max_concurrency", "abc").is_err());
    }

    #[test]
    fn speculation_knobs_parse() {
        let mut c = FlintConfig::default();
        assert!(!c.flint.speculation.enabled, "speculation is off by default, like Spark");
        assert_eq!(c.flint.speculation.multiplier, 1.5);
        assert_eq!(c.flint.speculation.quantile, 0.75);
        c.set("flint.speculation", "on").unwrap();
        assert!(c.flint.speculation.enabled);
        c.set("flint.speculation", "off").unwrap();
        assert!(!c.flint.speculation.enabled);
        c.set("flint.speculation", "true").unwrap();
        assert!(c.flint.speculation.enabled);
        c.set("flint.speculation.multiplier", "2.0").unwrap();
        c.set("flint.speculation.quantile", "0.5").unwrap();
        assert_eq!(c.flint.speculation.multiplier, 2.0);
        assert_eq!(c.flint.speculation.quantile, 0.5);
        assert!(c.set("flint.speculation", "maybe").is_err());
        // Straggler injection knobs live under sim (they model the
        // environment, not the engine).
        assert_eq!(c.sim.straggler_prob, 0.0, "injection off by default");
        c.set("sim.straggler_prob", "0.1").unwrap();
        c.set("sim.straggler_factor", "8.0").unwrap();
        c.set("sim.straggler_alpha", "1.5").unwrap();
        assert_eq!(c.sim.straggler_prob, 0.1);
        assert_eq!(c.sim.straggler_factor, 8.0);
        assert_eq!(c.sim.straggler_alpha, 1.5);
    }

    #[test]
    fn columnar_hot_path_knobs() {
        let mut c = FlintConfig::default();
        assert_eq!(c.flint.shuffle_codec, ShuffleCodec::Columnar, "columnar is the default");
        assert!(c.flint.scan_prune, "pruning is on by default");
        c.set("flint.shuffle.codec", "rows").unwrap();
        assert_eq!(c.flint.shuffle_codec, ShuffleCodec::Rows);
        c.set("flint.shuffle.codec", "columnar").unwrap();
        assert_eq!(c.flint.shuffle_codec, ShuffleCodec::Columnar);
        assert!(c.set("flint.shuffle.codec", "parquet").is_err());
        c.set("flint.scan.prune", "false").unwrap();
        assert!(!c.flint.scan_prune);
        c.set("flint.scan.prune", "true").unwrap();
        assert!(c.flint.scan_prune);
        assert!(c.set("flint.scan.prune", "maybe").is_err());
    }

    #[test]
    fn batch_rows_zero_rejected_at_parse_time() {
        let mut c = FlintConfig::default();
        c.set("flint.batch_rows", "512").unwrap();
        assert_eq!(c.flint.batch_rows, 512);
        let err = c.set("flint.batch_rows", "0").unwrap_err();
        assert!(err.contains("flint.batch_rows"), "{err}");
        assert!(err.contains("positive"), "{err}");
        assert_eq!(c.flint.batch_rows, 512, "failed override must not apply");
        assert!(c.set("flint.batch_rows", "-3").is_err());
        assert!(c.set("flint.batch_rows", "many").is_err());
    }

    #[test]
    fn sql_knobs_parse_and_round_trip() {
        let mut c = FlintConfig::default();
        assert!(c.flint.sql.optimizer, "optimizer is on by default");
        assert_eq!(c.flint.sql.broadcast_threshold_bytes, 64 * 1024 * 1024);

        c.set("flint.sql.optimizer", "off").unwrap();
        assert!(!c.flint.sql.optimizer);
        c.set("flint.sql.optimizer", "on").unwrap();
        assert!(c.flint.sql.optimizer);
        c.set("flint.sql.optimizer", "false").unwrap();
        assert!(!c.flint.sql.optimizer);
        c.set("flint.sql.optimizer", "true").unwrap();
        assert!(c.flint.sql.optimizer);
        assert!(c.set("flint.sql.optimizer", "maybe").is_err());

        c.set("flint.sql.broadcast_threshold_bytes", "0").unwrap();
        assert_eq!(c.flint.sql.broadcast_threshold_bytes, 0, "0 is legal: forces shuffle joins");
        c.set("flint.sql.broadcast_threshold_bytes", "1048576").unwrap();
        assert_eq!(c.flint.sql.broadcast_threshold_bytes, 1 << 20);
        assert!(c.set("flint.sql.broadcast_threshold_bytes", "-1").is_err());
        assert!(c.set("flint.sql.broadcast_threshold_bytes", "huge").is_err());
        assert_eq!(
            c.flint.sql.broadcast_threshold_bytes,
            1 << 20,
            "failed override must not apply"
        );

        // TOML layer reaches the same fields.
        let mut t = FlintConfig::default();
        parse::apply_toml(
            &mut t,
            "[flint.sql]\noptimizer = \"off\"\nbroadcast_threshold_bytes = 4096\n",
        )
        .unwrap();
        assert!(!t.flint.sql.optimizer);
        assert_eq!(t.flint.sql.broadcast_threshold_bytes, 4096);

        // And the JSON dump round-trips what was set.
        let j = t.to_json();
        let sql = j.get("flint").unwrap().get("sql").unwrap();
        assert_eq!(sql.get("optimizer").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(sql.get("broadcast_threshold_bytes").and_then(|v| v.as_u64()), Some(4096));
    }

    #[test]
    fn exchange_knobs_parse_and_validate() {
        let mut c = FlintConfig::default();
        assert_eq!(c.flint.shuffle_exchange, ShuffleExchange::Direct, "direct is the default");
        assert_eq!(c.flint.tree_fanout, 64);
        c.set("flint.shuffle.exchange", "tree").unwrap();
        assert_eq!(c.flint.shuffle_exchange, ShuffleExchange::Tree);
        c.set("flint.shuffle.exchange", "direct").unwrap();
        assert_eq!(c.flint.shuffle_exchange, ShuffleExchange::Direct);
        assert!(c.set("flint.shuffle.exchange", "ring").is_err());

        c.set("flint.shuffle.tree_fanout", "128").unwrap();
        assert_eq!(c.flint.tree_fanout, 128);
        for bad in ["0", "1", "-4", "wide"] {
            let err = c.set("flint.shuffle.tree_fanout", bad).unwrap_err();
            assert!(err.contains("flint.shuffle.tree_fanout"), "{err}");
        }
        assert_eq!(c.flint.tree_fanout, 128, "failed overrides must not apply");

        // JSON dump round-trips the exchange knobs.
        c.set("flint.shuffle.exchange", "tree").unwrap();
        let j = c.to_json();
        let f = j.get("flint").unwrap();
        assert_eq!(f.get("shuffle_exchange").and_then(|v| v.as_str()), Some("tree"));
        assert_eq!(f.get("tree_fanout").and_then(|v| v.as_u64()), Some(128));
    }

    #[test]
    fn tenant_quota_knobs_parse_and_round_trip() {
        let mut c = FlintConfig::default();
        assert!(c.flint.service.max_slots.is_empty());
        assert_eq!(c.flint.service.quota_of("anyone"), None, "uncapped by default");

        c.set("flint.service.max_slots.alice", "4").unwrap();
        c.set("flint.service.max_slots.bob", "1").unwrap();
        assert_eq!(c.flint.service.quota_of("alice"), Some(4));
        assert_eq!(c.flint.service.quota_of("bob"), Some(1));
        assert_eq!(c.flint.service.quota_of("carol"), None);
        for bad in ["0", "-1", "lots", "2.5"] {
            let err = c.set("flint.service.max_slots.alice", bad).unwrap_err();
            assert!(err.contains("flint.service.max_slots.alice"), "{err}");
        }
        assert_eq!(c.flint.service.quota_of("alice"), Some(4), "failed overrides must not apply");
        assert!(c.set("flint.service.max_slots.", "2").is_err(), "tenant name required");

        // TOML layer reaches the same map, and the JSON dump round-trips.
        let mut t = FlintConfig::default();
        parse::apply_toml(&mut t, "[flint.service.max_slots]\nalice = 4\nbob = 1\n").unwrap();
        assert_eq!(t.flint.service.quota_of("alice"), Some(4));
        assert_eq!(t.flint.service.quota_of("bob"), Some(1));
        let j = t.to_json();
        let q = j.get("flint").unwrap().get("service").unwrap().get("max_slots").unwrap();
        assert_eq!(q.get("alice").and_then(|v| v.as_u64()), Some(4));
        assert_eq!(q.get("bob").and_then(|v| v.as_u64()), Some(1));
    }

    #[test]
    fn cache_knobs_parse_validate_and_round_trip() {
        let mut c = FlintConfig::default();
        assert_eq!(c.flint.cache.capacity_bytes, 0, "cache is off by default");
        assert_eq!(c.flint.cache.tier, CacheTier::Both);
        assert_eq!(c.flint.lambda_keepalive_s, 0.0, "containers stay warm forever by default");

        c.set("flint.cache.capacity_bytes", "1048576").unwrap();
        assert_eq!(c.flint.cache.capacity_bytes, 1 << 20);
        c.set("flint.cache.capacity_bytes", "0").unwrap();
        assert_eq!(c.flint.cache.capacity_bytes, 0, "0 is legal: cache off");
        for bad in ["-1", "plenty", "1.5"] {
            let err = c.set("flint.cache.capacity_bytes", bad).unwrap_err();
            assert!(err.contains("flint.cache.capacity_bytes"), "{err}");
        }
        assert_eq!(c.flint.cache.capacity_bytes, 0, "failed overrides must not apply");

        c.set("flint.cache.tier", "memory").unwrap();
        assert_eq!(c.flint.cache.tier, CacheTier::Memory);
        c.set("flint.cache.tier", "s3").unwrap();
        assert_eq!(c.flint.cache.tier, CacheTier::S3);
        c.set("flint.cache.tier", "both").unwrap();
        assert_eq!(c.flint.cache.tier, CacheTier::Both);
        assert!(c.set("flint.cache.tier", "tape").is_err());

        c.set("flint.lambda.keepalive_s", "300").unwrap();
        assert_eq!(c.flint.lambda_keepalive_s, 300.0);
        c.set("flint.lambda.keepalive_s", "0").unwrap();
        assert_eq!(c.flint.lambda_keepalive_s, 0.0, "0 keepalive is legal: never expire");
        for bad in ["-1", "nan", "inf", "forever"] {
            let err = c.set("flint.lambda.keepalive_s", bad).unwrap_err();
            assert!(err.contains("flint.lambda.keepalive_s"), "{err}");
        }
        assert_eq!(c.flint.lambda_keepalive_s, 0.0, "failed overrides must not apply");

        // TOML layer reaches the same fields.
        let mut t = FlintConfig::default();
        parse::apply_toml(
            &mut t,
            "[flint.cache]\ncapacity_bytes = 4096\ntier = \"s3\"\n[flint.lambda]\nkeepalive_s = 60.0\n",
        )
        .unwrap();
        assert_eq!(t.flint.cache.capacity_bytes, 4096);
        assert_eq!(t.flint.cache.tier, CacheTier::S3);
        assert_eq!(t.flint.lambda_keepalive_s, 60.0);

        // And the JSON dump round-trips what was set.
        let j = t.to_json();
        let f = j.get("flint").unwrap();
        let cache = f.get("cache").unwrap();
        assert_eq!(cache.get("capacity_bytes").and_then(|v| v.as_u64()), Some(4096));
        assert_eq!(cache.get("tier").and_then(|v| v.as_str()), Some("s3"));
        assert_eq!(f.get("lambda_keepalive_s").and_then(|v| v.as_f64()), Some(60.0));
    }

    #[test]
    fn json_dump_contains_sections() {
        let j = FlintConfig::default().to_json();
        assert!(j.get("sim").is_some());
        assert!(j.get("flint").is_some());
    }

    #[test]
    fn service_knobs_parse_and_validate() {
        let mut c = FlintConfig::default();
        assert_eq!(c.flint.service.policy, ServicePolicy::Fair, "fair is the default");
        assert_eq!(c.flint.service.max_queued, 64);
        assert!(c.flint.service.weights.is_empty());
        assert_eq!(c.flint.service.weight_of("anyone"), 1.0);

        c.set("flint.service.policy", "fifo").unwrap();
        assert_eq!(c.flint.service.policy, ServicePolicy::Fifo);
        c.set("flint.service.policy", "weighted").unwrap();
        assert_eq!(c.flint.service.policy, ServicePolicy::Weighted);
        c.set("flint.service.policy", "fair").unwrap();
        assert_eq!(c.flint.service.policy, ServicePolicy::Fair);
        assert!(c.set("flint.service.policy", "lottery").is_err());

        c.set("flint.service.max_queued", "7").unwrap();
        assert_eq!(c.flint.service.max_queued, 7);
        let err = c.set("flint.service.max_queued", "0").unwrap_err();
        assert!(err.contains("flint.service.max_queued"), "{err}");
        assert!(err.contains("positive"), "{err}");
        assert_eq!(c.flint.service.max_queued, 7, "failed override must not apply");
        assert!(c.set("flint.service.max_queued", "-2").is_err());
        assert!(c.set("flint.service.max_queued", "lots").is_err());

        c.set("flint.service.weight.alice", "3.0").unwrap();
        c.set("flint.service.weight.bob", "0.5").unwrap();
        assert_eq!(c.flint.service.weight_of("alice"), 3.0);
        assert_eq!(c.flint.service.weight_of("bob"), 0.5);
        assert_eq!(c.flint.service.weight_of("carol"), 1.0);
        for bad in ["0", "-1.5", "nan", "inf", "heavy"] {
            let err = c.set("flint.service.weight.alice", bad).unwrap_err();
            assert!(err.contains("flint.service.weight.alice"), "{err}");
        }
        assert_eq!(c.flint.service.weight_of("alice"), 3.0, "failed overrides must not apply");
        assert!(c.set("flint.service.weight.", "1.0").is_err(), "tenant name required");
    }

    #[test]
    fn service_knobs_round_trip_through_json() {
        let mut c = FlintConfig::default();
        c.set("flint.service.policy", "weighted").unwrap();
        c.set("flint.service.max_queued", "12").unwrap();
        c.set("flint.service.weight.alice", "3.0").unwrap();
        c.set("flint.service.weight.bob", "0.25").unwrap();
        c.set("sim.straggler_containers", "16").unwrap();
        let j = c.to_json();
        let svc = j.get("flint").unwrap().get("service").unwrap();
        assert_eq!(svc.get("policy").and_then(|v| v.as_str()), Some("weighted"));
        assert_eq!(svc.get("max_queued").and_then(|v| v.as_u64()), Some(12));
        let w = svc.get("weights").unwrap();
        assert_eq!(w.get("alice").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(w.get("bob").and_then(|v| v.as_f64()), Some(0.25));
        assert_eq!(
            j.get("sim").unwrap().get("straggler_containers").and_then(|v| v.as_u64()),
            Some(16)
        );
    }
}
