//! Table I harness: measured-mode trials over the simulated stack.

use crate::compute::queries::QueryId;
use crate::config::FlintConfig;
use crate::cost::report::Cell;
use crate::data::{generate_taxi_dataset, Dataset};
use crate::exec::{ClusterEngine, ClusterMode, Engine, FlintEngine, QueryReport};
use crate::services::SimEnv;
use crate::util::stats::Summary;
use anyhow::Result;

/// Options for a Table I run.
#[derive(Debug, Clone)]
pub struct Table1Options {
    pub trips: u64,
    /// Flint trials (the paper: five, after warm-up).
    pub trials_flint: usize,
    /// Cluster trials (the paper: three, low variance).
    pub trials_cluster: usize,
    pub queries: Vec<QueryId>,
    /// Also compute the analytic paper-scale estimate per cell.
    pub paper_scale: bool,
}

impl Default for Table1Options {
    fn default() -> Self {
        Table1Options {
            trips: 1_000_000,
            trials_flint: 5,
            trials_cluster: 3,
            queries: QueryId::ALL.to_vec(),
            paper_scale: true,
        }
    }
}

/// One query's row: cells for Flint, PySpark, Spark (paper column order)
/// plus optional paper-scale estimates.
pub struct Table1Row {
    pub query: QueryId,
    pub cells: Vec<Cell>,
    /// `(latency_s, cost_usd)` per engine at 215 GB, when requested.
    pub paper_estimate: Option<Vec<(f64, f64)>>,
    /// Last Flint report (diagnostics for the detailed dump).
    pub flint_report: QueryReport,
}

/// Run the Table I experiment. One environment/dataset serves all
/// engines; cost is separated per trial via snapshots.
pub fn run_table1(config: &FlintConfig, opts: &Table1Options) -> Result<(Dataset, Vec<Table1Row>)> {
    let env = SimEnv::new(config.clone());
    let dataset = generate_taxi_dataset(&env, "trips", opts.trips);

    let flint = FlintEngine::new(env.clone());
    let pyspark = ClusterEngine::new(env.clone(), ClusterMode::PySpark);
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    // The paper measures after warm-up.
    flint.prewarm();

    let mut rows = Vec::new();
    for &q in &opts.queries {
        let mut cells = Vec::new();
        let mut flint_report = None;

        // Flint trials.
        let mut lat = Vec::new();
        let mut cost = Vec::new();
        let mut detail = None;
        for _ in 0..opts.trials_flint {
            let r = flint.run_query(q, &dataset)?;
            lat.push(r.latency_s);
            cost.push(r.cost_usd);
            detail = Some(r.cost.clone());
            flint_report = Some(r);
        }
        cells.push(Cell {
            latency: Summary::of(&lat),
            cost: Summary::of(&cost),
            cost_detail: detail.clone().unwrap_or_default(),
        });

        // Cluster trials (PySpark then Spark — paper column order).
        for engine in [&pyspark as &dyn Engine, &spark] {
            let mut lat = Vec::new();
            let mut cost = Vec::new();
            let mut detail = None;
            for _ in 0..opts.trials_cluster {
                let r = engine.run_query(q, &dataset)?;
                lat.push(r.latency_s);
                cost.push(r.cost_usd);
                detail = Some(r.cost.clone());
            }
            cells.push(Cell {
                latency: Summary::of(&lat),
                cost: Summary::of(&cost),
                cost_detail: detail.unwrap_or_default(),
            });
        }

        let flint_report = flint_report.expect("at least one flint trial");
        // Extension queries (Q6J) have no published Table I row to
        // extrapolate against; they get measured cells only.
        let paper_estimate = (opts.paper_scale && q.published_index().is_some()).then(|| {
            vec![
                crate::bench::paper::estimate(q, &flint_report, config, &dataset, PaperEngine::Flint),
                crate::bench::paper::estimate(q, &flint_report, config, &dataset, PaperEngine::PySpark),
                crate::bench::paper::estimate(q, &flint_report, config, &dataset, PaperEngine::Spark),
            ]
        });
        rows.push(Table1Row { query: q, cells, paper_estimate, flint_report });
    }
    Ok((dataset, rows))
}

pub use crate::bench::paper::PaperEngine;

/// Render rows in the paper's layout (measured mode).
pub fn render_measured(rows: &[Table1Row]) -> String {
    let table: Vec<(String, Vec<Cell>)> = rows
        .iter()
        .map(|r| (r.query.name().trim_start_matches('Q').to_string(), r.cells.clone()))
        .collect();
    // (Q6J renders as row "6J": measured latency/cost for the shuffle
    // join next to broadcast Q6's row 6.)
    crate::cost::report::render_table1(
        "Table I (measured mode: simulated stack, generated data)",
        &["Flint", "PySpark", "Spark"],
        &table,
        true,
    )
}

/// Render the paper-scale estimates next to the published numbers.
pub fn render_paper_scale(rows: &[Table1Row]) -> String {
    // Published Table I values for side-by-side comparison.
    const PUBLISHED: [(f64, f64, f64, f64, f64, f64); 7] = [
        (101.0, 211.0, 188.0, 0.20, 0.41, 0.37),
        (190.0, 316.0, 189.0, 0.59, 0.61, 0.37),
        (203.0, 314.0, 187.0, 0.68, 0.61, 0.36),
        (165.0, 312.0, 188.0, 0.48, 0.61, 0.36),
        (132.0, 225.0, 189.0, 0.33, 0.44, 0.37),
        (159.0, 312.0, 189.0, 0.45, 0.60, 0.37),
        (277.0, 337.0, 191.0, 0.56, 0.66, 0.37),
    ];
    let mut out = String::from(
        "## Table I (paper scale: 215 GiB / 1.3 B trips, analytic extrapolation)\n\n\
         |   | Flint (est/paper) | PySpark (est/paper) | Spark (est/paper) | \
         Flint $ (est/paper) | PySpark $ | Spark $ |\n|---|---|---|---|---|---|---|\n",
    );
    for row in rows {
        let Some(est) = &row.paper_estimate else { continue };
        // Extension queries carry no estimate (guarded in run_table1),
        // but be defensive: only rows with a published index render.
        let Some(qi) = row.query.published_index() else { continue };
        let p = PUBLISHED[qi];
        out.push_str(&format!(
            "| {} | {:.0} / {:.0} | {:.0} / {:.0} | {:.0} / {:.0} | {:.2} / {:.2} | {:.2} / {:.2} | {:.2} / {:.2} |\n",
            qi,
            est[0].0, p.0, est[1].0, p.1, est[2].0, p.2,
            est[0].1, p.3, est[1].1, p.4, est[2].1, p.5,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_table1_run_produces_all_rows() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let opts = Table1Options {
            trips: 10_000,
            trials_flint: 2,
            trials_cluster: 1,
            queries: vec![QueryId::Q0, QueryId::Q1],
            paper_scale: true,
        };
        let (_, rows) = run_table1(&cfg, &opts).unwrap();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.cells.len(), 3);
            assert!(row.cells.iter().all(|c| c.latency.mean > 0.0));
            assert!(row.cells.iter().all(|c| c.cost.mean > 0.0));
            let est = row.paper_estimate.as_ref().unwrap();
            assert_eq!(est.len(), 3);
            assert!(est.iter().all(|(l, c)| *l > 0.0 && *c > 0.0));
        }
        let text = render_measured(&rows);
        assert!(text.contains("| 0 |"), "{text}");
        let paper = render_paper_scale(&rows);
        assert!(paper.contains("| 1 |"), "{paper}");
    }

    #[test]
    fn q6j_gets_measured_cells_but_no_paper_row() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let opts = Table1Options {
            trips: 8_000,
            trials_flint: 1,
            trials_cluster: 1,
            queries: vec![QueryId::Q6J],
            paper_scale: true,
        };
        let (_, rows) = run_table1(&cfg, &opts).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].cells.iter().all(|c| c.latency.mean > 0.0));
        assert!(
            rows[0].paper_estimate.is_none(),
            "Q6J has no published Table I row to extrapolate against"
        );
        let text = render_measured(&rows);
        assert!(text.contains("| 6J |"), "{text}");
        assert!(!render_paper_scale(&rows).contains("| 6J |"));
    }
}
