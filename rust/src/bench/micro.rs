//! Microbenchmarks backing the paper's in-text claims (experiment index
//! M1, M2, A1 in DESIGN.md §6), plus the engine-extension ablations:
//! the straggler/speculation ablation (A4), the broadcast-vs-shuffle
//! join crossover study (A5, the PR 3 join follow-up), the multi-tenant
//! concurrency ablation (A8, the service layer), the scale-out
//! exchange sweep (A10: direct vs tree S3 exchange, and the per-edge
//! backend auto-selection gate), and the lineage-cache ablation (A11:
//! cold build vs warm cached re-run, plus the capacity-0 off switch's
//! byte-identity guarantee).

use crate::compute::oracle;
use crate::compute::queries::QueryId;
use crate::compute::value::Value;
use crate::config::{FlintConfig, ShuffleBackend, ShuffleCodec};
use crate::data::weather::WeatherTable;
use crate::data::{generate_taxi_dataset, Dataset, INPUT_BUCKET, SHUFFLE_BUCKET};
use crate::exec::{Engine, FlintContext, FlintEngine};
use crate::plan::{interp, kernel_plan, Action, StageCompute};
use crate::services::SimEnv;
use crate::simtime::{ScheduleMode, ServicePolicy, Timeline};
use crate::sql::{self, JoinStrategy};
use anyhow::{anyhow, ensure, Result};

/// M1 — single-stream S3 read throughput: boto-class (Flint) vs
/// Hadoop-class (Spark), the paper's explanation for Q0. Returns modeled
/// `(flint_mbps_effective, spark_mbps_effective)` for `object_mb`.
pub fn s3_throughput(cfg: &FlintConfig, object_mb: usize) -> Result<(f64, f64)> {
    let env = SimEnv::new(cfg.clone());
    env.s3().create_bucket("bench");
    let bytes = object_mb * 1024 * 1024;
    env.s3().put_object("bench", "blob", vec![0u8; bytes])?;
    let (_, t_flint) = env.s3().get_object("bench", "blob", env.flint_read_profile())?;
    let (_, t_spark) = env.s3().get_object("bench", "blob", env.spark_read_profile())?;
    Ok((bytes as f64 / t_flint / 1e6, bytes as f64 / t_spark / 1e6))
}

/// M2 — cold vs warm invocation latency and the cost of chaining.
/// Returns `(cold_latency_s, warm_latency_s, chained_q0_latency_s,
/// unchained_q0_latency_s, chain_links)`.
pub fn cold_warm_chain(cfg: &FlintConfig, trips: u64) -> Result<(f64, f64, f64, f64, u64)> {
    // Cold run.
    let env = SimEnv::new(cfg.clone());
    let ds = generate_taxi_dataset(&env, "trips", trips);
    let flint = FlintEngine::new(env.clone());
    let cold = flint.run_query(QueryId::Q0, &ds)?;
    // Warm run.
    let warm = flint.run_query(QueryId::Q0, &ds)?;

    // Chained run: Python-era per-row compute (compute_scale) on big
    // splits, with a duration cap that forces tasks to checkpoint and
    // chain mid-split. Q1's per-batch chain points give fine-grained
    // checkpoints (Q0 counts in coarse blocks).
    let chain_trips = trips.max(400_000);
    let mut chain_cfg = cfg.clone();
    chain_cfg.data.object_bytes = 8 * 1024 * 1024;
    chain_cfg.flint.input_split_bytes = 8 * 1024 * 1024;
    chain_cfg.sim.compute_scale = 50.0; // force compute-bound tasks
    chain_cfg.sim.lambda_time_limit_s = 1.0;
    // Wide margin: the chain check runs once per batch, so the billed
    // duration can overshoot the budget by up to one batch of (scaled)
    // compute — keep that comfortably under the cap even for contended
    // debug builds.
    chain_cfg.sim.lambda_chain_margin_s = 0.3;
    let env2 = SimEnv::new(chain_cfg.clone());
    let ds2 = generate_taxi_dataset(&env2, "trips", chain_trips);
    let flint2 = FlintEngine::new(env2.clone());
    flint2.prewarm();
    let chained = flint2.run_query(QueryId::Q1, &ds2)?;

    // Same workload without the cap: the chaining-overhead baseline.
    let mut free_cfg = chain_cfg;
    free_cfg.sim.lambda_time_limit_s = 300.0;
    let env3 = SimEnv::new(free_cfg);
    let ds3 = generate_taxi_dataset(&env3, "trips", chain_trips);
    let flint3 = FlintEngine::new(env3.clone());
    flint3.prewarm();
    let unchained = flint3.run_query(QueryId::Q1, &ds3)?;

    Ok((
        cold.latency_s,
        warm.latency_s,
        chained.latency_s,
        unchained.latency_s,
        chained.chains,
    ))
}

/// A1 — the §VI shuffle ablation: the same query through the SQS backend
/// (the paper's design) and the S3 backend (Qubole's). The SQS backend
/// additionally reports the pipelined DAG clock (reducers long-poll
/// while mappers flush); the S3 backend's one-shot list-then-get
/// shuffle cannot overlap, so it only has a barrier row. One execution
/// per backend measures the task durations; the driver computes both
/// schedules from them, so the barrier/pipelined pair is exact (same
/// run, no cross-run noise). Returns
/// `(backend+schedule, latency_s, cost_usd, shuffle_msgs)` rows in the
/// order sqs+barrier, sqs+pipelined, s3+barrier.
pub fn shuffle_ablation(
    cfg: &FlintConfig,
    trips: u64,
    query: QueryId,
) -> Result<Vec<(String, f64, f64, u64)>> {
    let mut out = Vec::new();
    for backend in [ShuffleBackend::Sqs, ShuffleBackend::S3] {
        let mut c = cfg.clone();
        c.flint.shuffle_backend = backend;
        let env = SimEnv::new(c);
        let ds = generate_taxi_dataset(&env, "trips", trips);
        let flint = FlintEngine::new(env.clone());
        flint.prewarm();
        let r = flint.run_query(query, &ds)?;
        let backend_name = match backend {
            ShuffleBackend::Sqs => "sqs",
            ShuffleBackend::S3 => "s3",
            ShuffleBackend::Auto => "auto",
        };
        out.push((
            format!("{backend_name}+barrier"),
            r.barrier_latency_s,
            r.cost_usd,
            r.shuffle_msgs,
        ));
        if backend == ShuffleBackend::Sqs {
            out.push((
                format!("{backend_name}+pipelined"),
                r.pipelined_latency_s,
                r.cost_usd,
                r.shuffle_msgs,
            ));
        }
    }
    Ok(out)
}

/// One query's row of the straggler/speculation ablation (A4).
#[derive(Debug, Clone)]
pub struct StragglerRow {
    pub query: QueryId,
    /// Pipelined clock with the injected straggler, no speculation.
    pub plain_pipelined_s: f64,
    /// Pipelined clock with speculative backups (same execution).
    pub spec_pipelined_s: f64,
    /// Serial barrier clock (same execution, for scale).
    pub barrier_s: f64,
    /// Occupied-but-idle long-polling seconds (the overlap's cost side).
    pub idle_s: f64,
    pub launches: u64,
    pub wins: u64,
    pub cost_usd: f64,
}

/// A4 — straggler/speculation ablation: inject a decisive heavy-tailed
/// straggler into each query's scan stage and run once with speculation
/// enabled. Both the speculative and the speculation-free pipelined
/// clocks come from that single execution (same measured attempt
/// durations), so `spec < plain` is an exact comparison, not cross-run
/// noise — pipelined+speculation must strictly beat plain pipelined on
/// every multi-stage query. Results are oracle-checked: racing duplicate
/// attempts must never change an answer.
pub fn straggler_ablation(
    cfg: &FlintConfig,
    trips: u64,
    queries: &[QueryId],
) -> Result<Vec<StragglerRow>> {
    let mut out = Vec::new();
    for &q in queries {
        let mut c = cfg.clone();
        c.flint.shuffle_backend = ShuffleBackend::Sqs;
        c.flint.scheduler = ScheduleMode::Pipelined;
        c.flint.speculation.enabled = true;
        let env = SimEnv::new(c);
        let ds = generate_taxi_dataset(&env, "trips", trips);
        let flint = FlintEngine::new(env.clone());
        flint.prewarm();
        // One decisive straggler on the scan stage's first task, primary
        // attempt only — the backup lands on a clean container ("slow
        // node, not slow work"). Deterministic, so runs are repeatable.
        env.failure().force_straggler(0, 0, 0, 10.0);
        let expect = oracle::evaluate(&env, &ds, q);
        let r = flint.run_query(q, &ds)?;
        ensure!(
            r.result.approx_eq(&expect),
            "{q}: speculative re-execution changed the answer"
        );
        out.push(StragglerRow {
            query: q,
            plain_pipelined_s: r.pipelined_nospec_latency_s,
            spec_pipelined_s: r.pipelined_latency_s,
            barrier_s: r.barrier_latency_s,
            idle_s: r.pipelined_idle_s,
            launches: r.speculative_launches,
            wins: r.speculative_wins,
            cost_usd: r.cost_usd,
        });
    }
    Ok(out)
}

/// One dimension-table size of the join crossover study (A5).
#[derive(Debug, Clone)]
pub struct JoinCrossRow {
    pub dim_bytes: u64,
    /// Q6: every map task GETs the whole dimension table (broadcast).
    pub broadcast_s: f64,
    /// Q6J: the dimension rides the shuffle through the join stage.
    pub shuffle_s: f64,
    pub broadcast_usd: f64,
    pub shuffle_usd: f64,
}

/// A5 — broadcast-vs-shuffle join crossover: sweep the dimension-table
/// (weather) size on the Q6/Q6J pair. Small tables favour the broadcast
/// (no join stage, no extra shuffle hop); as the table grows, the
/// broadcast's per-map-task GET of the whole table dominates while the
/// shuffle join scans it once — the classic exchange-operator crossover.
/// Returns the swept rows plus the first size where the shuffle join
/// wins (`None` when broadcast wins everywhere in the sweep).
pub fn join_crossover(
    cfg: &FlintConfig,
    trips: u64,
    dim_targets: &[u64],
) -> Result<(Vec<JoinCrossRow>, Option<u64>)> {
    let mut rows = Vec::new();
    for &target in dim_targets {
        let env = SimEnv::new(cfg.clone());
        let mut ds = generate_taxi_dataset(&env, "trips", trips);
        if target > ds.weather_bytes {
            inflate_weather(&env, &mut ds, target)?;
        }
        let flint = FlintEngine::new(env.clone());
        flint.prewarm();
        let broadcast = flint.run_query(QueryId::Q6, &ds)?;
        let shuffle = flint.run_query(QueryId::Q6J, &ds)?;
        rows.push(JoinCrossRow {
            dim_bytes: ds.weather_bytes,
            broadcast_s: broadcast.latency_s,
            shuffle_s: shuffle.latency_s,
            broadcast_usd: broadcast.cost_usd,
            shuffle_usd: shuffle.cost_usd,
        });
    }
    let crossover = rows
        .iter()
        .find(|r| r.shuffle_s < r.broadcast_s)
        .map(|r| r.dim_bytes);
    Ok((rows, crossover))
}

/// Grow the weather side table to ~`target` bytes without changing its
/// *parsed* content: each row's precipitation keeps its value but gains
/// trailing fractional zeros, so Q6's broadcast lookup and Q6J's
/// shuffled dimension rows still agree with the oracle byte-for-value.
fn inflate_weather(env: &SimEnv, ds: &mut Dataset, target: u64) -> Result<()> {
    let (obj, _) = env
        .s3()
        .get_object(INPUT_BUCKET, &ds.weather_key, env.flint_read_profile())
        .map_err(|e| anyhow!("weather table: {e}"))?;
    let table = WeatherTable::from_csv(obj.bytes()).ok_or_else(|| anyhow!("weather corrupt"))?;
    let rows = table.precip.len().max(1);
    let base_len = obj.bytes().len() as u64;
    let pad = (target.saturating_sub(base_len) as usize).div_ceil(rows);
    let zeros = "0".repeat(pad);
    let mut out = String::with_capacity(target as usize + rows * 16);
    for (i, p) in table.precip.iter().enumerate() {
        out.push_str(&format!("{i},{p:.3}{zeros}\n"));
    }
    let body = out.into_bytes();
    ds.weather_bytes = body.len() as u64;
    env.s3()
        .put_object(INPUT_BUCKET, &ds.weather_key, body)
        .map_err(|e| anyhow!("weather put: {e}"))?;
    Ok(())
}

/// A6 — shuffle codec ablation: each query once per wire codec, in a
/// fresh environment each time. Total encoded shuffle-record bytes come
/// from the driver's per-edge accounting (`edge_shuffle[].bytes`), and
/// both runs are oracle-checked, so the ratio is a pure wire-format
/// comparison over identical logical record streams. Returns
/// `(query, rows_bytes, columnar_bytes)` per query.
pub fn codec_byte_ratio(
    cfg: &FlintConfig,
    trips: u64,
    queries: &[QueryId],
) -> Result<Vec<(QueryId, u64, u64)>> {
    let mut out = Vec::new();
    for &q in queries {
        let mut bytes = [0u64; 2];
        for (i, codec) in [ShuffleCodec::Rows, ShuffleCodec::Columnar].into_iter().enumerate() {
            let mut c = cfg.clone();
            c.flint.shuffle_codec = codec;
            let env = SimEnv::new(c);
            let ds = generate_taxi_dataset(&env, "trips", trips);
            let flint = FlintEngine::new(env.clone());
            flint.prewarm();
            let expect = oracle::evaluate(&env, &ds, q);
            let r = flint.run_query(q, &ds)?;
            ensure!(r.result.approx_eq(&expect), "{q}/{codec:?}: codec changed the answer");
            bytes[i] = r.edge_shuffle.iter().map(|e| e.bytes).sum();
        }
        out.push((q, bytes[0], bytes[1]));
    }
    Ok(out)
}

/// A7 — statistics-based scan pruning ablation: Q1 narrowed to a
/// dropoff-day window through the typed spec predicate, run once with
/// `flint.scan.prune` on and once off. The manifest's per-object
/// min/max day stats let the pruned run skip fetching splits entirely
/// outside the window, so it must issue fewer S3 GETs while producing
/// the identical histogram (a pruned split is indistinguishable from
/// one whose rows all failed the predicate). Returns
/// `(pruned_gets, unpruned_gets, splits_pruned)`.
pub fn pruning_ablation(
    cfg: &FlintConfig,
    trips: u64,
    day_lo: i32,
    day_hi: i32,
) -> Result<(u64, u64, u64)> {
    let mut gets = [0u64; 2];
    let mut splits_pruned = 0u64;
    let mut results = Vec::new();
    for (i, prune) in [true, false].into_iter().enumerate() {
        let mut c = cfg.clone();
        c.flint.scan_prune = prune;
        let env = SimEnv::new(c);
        let ds = generate_taxi_dataset(&env, "trips", trips);
        let mut plan = kernel_plan(QueryId::Q1, &ds, env.config());
        for stage in &mut plan.stages {
            match &mut stage.compute {
                StageCompute::KernelScan { spec } | StageCompute::KernelReduce { spec } => {
                    *spec = spec.with_day_range(day_lo, day_hi);
                }
                _ => {}
            }
        }
        let flint = FlintEngine::new(env.clone());
        flint.prewarm();
        let before = env.metrics().get("s3.get");
        let r = flint.run_plan(&plan)?;
        gets[i] = env.metrics().get("s3.get") - before;
        if prune {
            splits_pruned = env.metrics().get("scan.splits_pruned");
        }
        results.push(r.result);
    }
    ensure!(results[0].approx_eq(&results[1]), "pruning changed the answer");
    Ok((gets[0], gets[1], splits_pruned))
}

/// A3-adjacent — elasticity sweep: the same query at increasing Lambda
/// concurrency limits. The paper's pay-as-you-go argument in one curve:
/// latency drops with concurrency while the *cost stays flat* (you pay
/// for GB-seconds of work, not for provisioned capacity). Returns
/// `(concurrency, latency_s, cost_usd)` rows.
pub fn elasticity_sweep(
    cfg: &FlintConfig,
    trips: u64,
    query: QueryId,
    levels: &[usize],
) -> Result<Vec<(usize, f64, f64)>> {
    let mut out = Vec::new();
    for &slots in levels {
        let mut c = cfg.clone();
        c.sim.max_concurrency = slots;
        let env = SimEnv::new(c);
        let ds = generate_taxi_dataset(&env, "trips", trips);
        let flint = FlintEngine::new(env.clone());
        flint.prewarm();
        let r = flint.run_query(query, &ds)?;
        out.push((slots, r.latency_s, r.cost_usd));
    }
    Ok(out)
}

/// One (concurrency × policy) cell of the multi-tenancy ablation (A8).
#[derive(Debug, Clone)]
pub struct ConcurrencyRow {
    pub policy: ServicePolicy,
    pub queries: usize,
    /// When the last query finished on the shared service clock.
    pub makespan_s: f64,
    /// Completed queries per shared-clock second.
    pub throughput_qps: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub idle_s: f64,
    pub cost_usd: f64,
}

/// The service's hour-histogram workload: a two-stage shuffle lineage
/// (scan → 4-way reduce) kept narrower than the slot pool, so the
/// arbitration policy — not raw capacity — decides each query's tail.
fn service_workload(sc: &crate::exec::FlintContext) -> crate::plan::Rdd {
    use crate::compute::value::Value;
    sc.text_file(INPUT_BUCKET, "trips/")
        .map(|line| {
            let text = line.as_str().expect("text input");
            let hour = crate::data::schema::TripRecord::parse_csv(text.as_bytes())
                .map(|r| crate::data::chrono::hour_of_day(r.dropoff_ts) as i64)
                .unwrap_or(0);
            Value::pair(Value::I64(hour), Value::I64(1))
        })
        .reduce_by_key(4, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
}

/// A8 — multi-tenant concurrency ablation: `n` tenants each submit one
/// copy of the same two-stage query as a burst at t=0, and the sweep
/// crosses burst size with the service's arbitration policy. FIFO's
/// head-of-line blocking shows up as a long latency tail (late arrivals
/// wait for the whole queue); fair sharing packs the same work into the
/// same makespan (work conservation — throughput must not regress) while
/// every tenant progresses, collapsing p99 toward p50. Each cell also
/// re-checks ledger conservation: Σ per-tenant ledgers == pool spend.
pub fn concurrency_ablation(
    cfg: &FlintConfig,
    trips: u64,
    concurrency: &[usize],
    policies: &[ServicePolicy],
) -> Result<Vec<ConcurrencyRow>> {
    let mut out = Vec::new();
    for &n in concurrency {
        for &policy in policies {
            let mut c = cfg.clone();
            c.flint.service.policy = policy;
            let env = SimEnv::new(c);
            generate_taxi_dataset(&env, "trips", trips);
            let service = crate::exec::FlintService::new(env.clone());
            service.prewarm();
            let sc = service.session("bench");
            let rdd = service_workload(&sc);
            for i in 0..n {
                service
                    .submit(&format!("tenant{i}"), &rdd, crate::plan::Action::Collect)
                    .map_err(|e| anyhow!("admission failed: {e}"))?;
            }
            let report = service.run()?;
            ensure!(report.makespan_s > 0.0, "empty service schedule");
            let ledger_sum: f64 = report.ledgers.values().map(|l| l.total_usd()).sum();
            ensure!(
                (ledger_sum - report.run_cost.total()).abs() < 1e-9,
                "ledger conservation broke: {ledger_sum} vs {}",
                report.run_cost.total()
            );
            let lat: Vec<f64> =
                report.queries.iter().map(|q| q.window.latency_s).collect();
            out.push(ConcurrencyRow {
                policy,
                queries: n,
                makespan_s: report.makespan_s,
                throughput_qps: n as f64 / report.makespan_s,
                p50_s: crate::util::stats::percentile(&lat, 50.0),
                p99_s: crate::util::stats::percentile(&lat, 99.0),
                idle_s: report.idle_s,
                cost_usd: report.run_cost.total(),
            });
        }
    }
    Ok(out)
}

/// One Table I query under the SQL frontend, optimizer on vs off.
#[derive(Debug, Clone)]
pub struct SqlAblationRow {
    pub query: QueryId,
    pub on_latency_s: f64,
    pub off_latency_s: f64,
    pub on_usd: f64,
    pub off_usd: f64,
    /// The optimizer's join pick, when the query joins.
    pub join_strategy: Option<&'static str>,
}

/// Lineage-interpreter line source over the simulated object store —
/// the oracle side of the SQL ablation reads the exact bytes the
/// engine scans.
fn s3_lines(env: &SimEnv) -> impl Fn(&str, &str) -> Vec<String> + '_ {
    move |bucket, prefix| {
        let mut listed = env.s3().list(bucket, prefix).unwrap_or_default();
        listed.sort();
        let mut out = Vec::new();
        for (key, _) in listed {
            if let Ok((obj, _)) = env.s3().get_object(bucket, &key, env.flint_read_profile()) {
                out.extend(String::from_utf8_lossy(obj.bytes()).lines().map(String::from));
            }
        }
        out
    }
}

/// A9 — SQL optimizer ablation: every Table I query (plus Q6J, forced
/// through the shuffle with `broadcast_threshold_bytes = 0`) compiled
/// from its SQL text twice, `flint.sql.optimizer` on vs off, in fresh
/// environments. Both runs are oracle-checked against the lineage
/// interpreter over the same objects, and both settings must produce
/// identical shaped rows — the rewriter and the cost-based planner may
/// only change *how* a query runs, never its answer. Returns one row
/// per query with the two latencies/costs and the join pick.
pub fn sql_optimizer_ablation(cfg: &FlintConfig, trips: u64) -> Result<Vec<SqlAblationRow>> {
    let mut out = Vec::new();
    for q in QueryId::ALL_WITH_JOINS {
        let text = sql::table1_sql(q);
        let mut lat = [0.0f64; 2];
        let mut usd = [0.0f64; 2];
        let mut rows_by_setting: Vec<Vec<Vec<Value>>> = Vec::new();
        let mut join_strategy = None;
        for (i, optimizer) in [true, false].into_iter().enumerate() {
            let mut c = cfg.clone();
            c.flint.sql.optimizer = optimizer;
            if q == QueryId::Q6J {
                c.flint.sql.broadcast_threshold_bytes = 0;
            }
            let env = SimEnv::new(c);
            let ds = generate_taxi_dataset(&env, "trips", trips);
            let sc = FlintContext::new(env.clone());
            sc.prewarm();
            sc.register_manifest(&ds);
            let job = sc.sql_job(text).map_err(|e| anyhow!("{q} compile: {e}"))?;
            if optimizer {
                join_strategy = job.choice.join.as_ref().map(|j| j.strategy.name());
            }
            // One execution yields both the measurement and the rows.
            let plan = sc.lower(&job.rdd, Action::Collect);
            let engine = sc.flint_engine().expect("serverless session");
            let before = env.cost().snapshot();
            let run = engine.run_plan_raw(&plan)?;
            let cost = env.cost().snapshot().since(&before);
            lat[i] = run.latency_s;
            usd[i] = cost.total();
            let got = job.shape(run.out.into_values()?);
            // Oracle: interpret the same lineage over the same lines
            // (outside the measured window).
            let lines = s3_lines(&env);
            let expect = job.shape(interp::interpret(&job.rdd, &lines));
            ensure!(
                got == expect,
                "{q} optimizer={optimizer}: engine rows diverge from the interpreter oracle"
            );
            rows_by_setting.push(got);
        }
        ensure!(
            rows_by_setting[0] == rows_by_setting[1],
            "{q}: the optimizer changed the answer"
        );
        out.push(SqlAblationRow {
            query: q,
            on_latency_s: lat[0],
            off_latency_s: lat[1],
            on_usd: usd[0],
            off_usd: usd[1],
            join_strategy,
        });
    }
    Ok(out)
}

/// A9 companion — does the planner's cost model agree with
/// measurement? Reuses the A5 sweep: at each dimension-table target the
/// Q6/Q6J pair is actually run (measured winner), then
/// `choose_join_strategy` is asked what it would pick for those byte
/// sizes. Returns `(dim_bytes, measured, planned)` rows; calibration
/// holds when the two columns agree on both sides of the crossover.
pub fn sql_cbo_agreement(
    cfg: &FlintConfig,
    trips: u64,
    dim_targets: &[u64],
) -> Result<Vec<(u64, JoinStrategy, JoinStrategy)>> {
    let (rows, _) = join_crossover(cfg, trips, dim_targets)?;
    // Probe-side bytes from one generated layout (the dataset generator
    // is seeded, so every sweep env sees the same trips objects).
    let env = SimEnv::new(cfg.clone());
    let ds = generate_taxi_dataset(&env, "trips", trips);
    let probe_bytes: u64 = ds.objects.iter().map(|(_, b)| *b).sum();
    Ok(rows
        .into_iter()
        .map(|r| {
            let measured = if r.shuffle_s < r.broadcast_s {
                JoinStrategy::Shuffle
            } else {
                JoinStrategy::Broadcast
            };
            let (planned, _, _) =
                crate::sql::physical::choose_join_strategy(cfg, probe_bytes, r.dim_bytes);
            (r.dim_bytes, measured, planned)
        })
        .collect())
}

/// One (producers × partitions) point of the A10 exchange sweep.
#[derive(Debug, Clone)]
pub struct ExchangePoint {
    pub producers: u32,
    pub partitions: u32,
    /// Total S3 requests (PUT + GET + LIST + rename) for the whole
    /// exchange: producer writes, the merge level (tree only), and
    /// every consumer's drain.
    pub direct_requests: u64,
    pub tree_requests: u64,
    /// Modeled wall clock. Each level is a parallel wave, so the wall
    /// is the slowest producer, plus the merge level's slowest task
    /// (tree only), plus the slowest consumer drain.
    pub direct_wall_s: f64,
    pub tree_wall_s: f64,
}

/// A10 — multi-level exchange sweep: a synthetic P-producer ×
/// R-partition S3 shuffle edge through the direct exchange (one object
/// per producer × partition) and the tree exchange (combined
/// producer-group objects plus a merge level), with the tree forced on
/// at every point (fan-out threshold 2) so both sides of the crossover
/// are measured. Every producer writes the same records through both
/// topologies and every partition's drained record stream is checked
/// identical — the sweep prices direct's O(P·R) object count against
/// tree's O((P+R)·√n) without paying for full queries at thousand-way
/// fan-outs.
pub fn exchange_sweep(cfg: &FlintConfig, points: &[(u32, u32)]) -> Result<Vec<ExchangePoint>> {
    use crate::exec::shuffle::{
        merge_tree_level, tree_plan, EdgeExchange, ShuffleReader, ShuffleRec, ShuffleWriter,
        Transport,
    };
    let mut out = Vec::new();
    for &(producers, partitions) in points {
        let plan = tree_plan(producers, partitions, 2)
            .ok_or_else(|| anyhow!("degenerate sweep point {producers}x{partitions}"))?;
        let mut requests = [0u64; 2];
        let mut walls = [0.0f64; 2];
        let mut streams: Vec<Vec<Vec<ShuffleRec>>> = Vec::new();
        for tree in [false, true] {
            // A fresh env per topology isolates the request counters.
            let env = SimEnv::new(cfg.clone());
            env.s3().create_bucket(SHUFFLE_BUCKET);
            let plan_id = if tree { "a10-tree" } else { "a10-direct" };
            let mut wall = 0.0f64;
            for p in 0..producers {
                let mut tl = Timeline::new();
                let mut w = ShuffleWriter::new(
                    &env,
                    Transport::S3,
                    plan_id,
                    0,
                    vec![1],
                    p as u64,
                    partitions,
                    None,
                );
                if tree {
                    w = w.with_edges(vec![EdgeExchange {
                        transport: Transport::S3,
                        tree_groups: Some(plan.consumer_groups),
                    }]);
                }
                for part in 0..partitions {
                    let key = p as i64 * partitions as i64 + part as i64;
                    let rec = ShuffleRec::Kernel { key, sum: key as f64, count: 1.0 };
                    w.write(part, &rec, &mut tl)?;
                }
                w.flush_all(&mut tl)?;
                wall = wall.max(tl.total());
            }
            if tree {
                let report = merge_tree_level(&env, plan_id, 0, 1, &plan)?;
                wall += report.task_durations.iter().cloned().fold(0.0, f64::max);
            }
            let mut drained: Vec<Vec<ShuffleRec>> = Vec::new();
            let mut drain_wall = 0.0f64;
            for part in 0..partitions {
                let mut tl = Timeline::new();
                let mut r =
                    ShuffleReader::new(&env, Transport::S3, plan_id, 0, 1, part, true);
                let read = r.drain(&mut tl)?;
                r.ack(&mut tl)?;
                drain_wall = drain_wall.max(tl.total());
                drained.push(read.records);
            }
            wall += drain_wall;
            let m = env.metrics();
            requests[tree as usize] =
                m.get("s3.put") + m.get("s3.get") + m.get("s3.list") + m.get("s3.rename");
            walls[tree as usize] = wall;
            streams.push(drained);
        }
        ensure!(
            streams[0] == streams[1],
            "{producers}x{partitions}: tree drain diverged from direct"
        );
        out.push(ExchangePoint {
            producers,
            partitions,
            direct_requests: requests[0],
            tree_requests: requests[1],
            direct_wall_s: walls[0],
            tree_wall_s: walls[1],
        });
    }
    Ok(out)
}

/// A10 — per-edge backend auto-selection: the same query through the
/// fixed SQS and S3 backends and `flint.shuffle.backend = auto`, which
/// picks payload-inline, SQS, or S3 per DAG edge from the calibrated
/// cost model. Every run is oracle-checked, so the three backends'
/// answers are pinned identical. Returns `(query, sqs_s, s3_s, auto_s)`
/// rows; auto must never lose to the better fixed backend by more than
/// schedule jitter.
pub fn backend_auto_ablation(
    cfg: &FlintConfig,
    trips: u64,
    queries: &[QueryId],
) -> Result<Vec<(QueryId, f64, f64, f64)>> {
    let mut out = Vec::new();
    for &q in queries {
        let mut lat = [0.0f64; 3];
        let backends = [ShuffleBackend::Sqs, ShuffleBackend::S3, ShuffleBackend::Auto];
        for (i, backend) in backends.into_iter().enumerate() {
            let mut c = cfg.clone();
            c.flint.shuffle_backend = backend;
            let env = SimEnv::new(c);
            let ds = generate_taxi_dataset(&env, "trips", trips);
            let flint = FlintEngine::new(env.clone());
            flint.prewarm();
            let expect = oracle::evaluate(&env, &ds, q);
            let r = flint.run_query(q, &ds)?;
            ensure!(
                r.result.approx_eq(&expect),
                "{q}: the {backend:?} backend changed the answer"
            );
            lat[i] = r.latency_s;
        }
        out.push((q, lat[0], lat[1], lat[2]));
    }
    Ok(out)
}

/// One workload of the lineage-cache ablation (A11).
#[derive(Debug, Clone)]
pub struct CacheAblationRow {
    pub name: &'static str,
    /// First run: the full scan plus the cache-build sub-plan (the
    /// build's latency and spend fold into this report).
    pub cold_s: f64,
    /// Re-run of the same handles: a truncated plan over the cached cut.
    pub warm_s: f64,
    pub cold_gb_s: f64,
    pub warm_gb_s: f64,
    pub cold_usd: f64,
    pub warm_usd: f64,
    pub builds: u64,
    pub hits: u64,
}

/// A11 — lineage-cache ablation: a Table I-style aggregation and a
/// Q6J-style day join, each with a `cache()` marker over its parsed
/// trips scan, run twice through one session with the cache enabled.
/// The lineages are built ONCE and reused: the registry keys on the
/// canonical lineage fingerprint, which includes closure identity for
/// dyn ops, so rebuilt closures would be distinct entries, not hits.
/// The cold run pays the materialization (its latency and spend fold
/// into the cold report); the warm run compiles a truncated plan whose
/// scan stage reads the cached cut — memory tier on warm containers,
/// committed S3 parts otherwise. Answers are checked against the
/// lineage interpreter over the exact bytes the engine scans. Returns
/// one row per workload; callers gate warm < cold on latency AND
/// GB-seconds.
pub fn cache_ablation(cfg: &FlintConfig, trips: u64) -> Result<Vec<CacheAblationRow>> {
    let mut c = cfg.clone();
    c.flint.cache.capacity_bytes = 4 << 30;
    let env = SimEnv::new(c.clone());
    let ds = generate_taxi_dataset(&env, "trips", trips);
    let sc = FlintContext::new(env.clone());
    sc.prewarm();

    // Table I-style: parse the dropoff hour once, cache the parsed
    // pairs, aggregate.
    let hist = sc
        .text_file(INPUT_BUCKET, "trips/")
        .map(|line| {
            let text = line.as_str().expect("text input");
            let hour = crate::data::schema::TripRecord::parse_csv(text.as_bytes())
                .map(|r| crate::data::chrono::hour_of_day(r.dropoff_ts) as i64)
                .unwrap_or(0);
            Value::pair(Value::I64(hour), Value::I64(1))
        })
        .cache()
        .reduce_by_key(8, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));

    // Q6J-style: fares keyed by dropoff day, cached below the cogroup
    // against the (uncached) weather dimension — the warm run re-reads
    // only the fact side's cut, the dimension scan still runs.
    let day_fares = sc
        .text_file(INPUT_BUCKET, "trips/")
        .map(|line| {
            let text = line.as_str().expect("text input");
            let (day, cents) = crate::data::schema::TripRecord::parse_csv(text.as_bytes())
                .map(|r| {
                    (
                        crate::data::chrono::day_index(r.dropoff_ts) as i64,
                        (r.total_amount as f64 * 100.0).round() as i64,
                    )
                })
                .unwrap_or((0, 0));
            Value::pair(Value::I64(day), Value::I64(cents))
        })
        .cache();
    let weather = sc.text_file(INPUT_BUCKET, &ds.weather_key).map(|line| {
        let text = line.as_str().expect("text input");
        let mut cols = text.split(',');
        let day = cols.next().and_then(|v| v.parse::<i64>().ok()).unwrap_or(-1);
        let milli = cols
            .next()
            .and_then(|v| v.parse::<f64>().ok())
            .map(|p| (p * 1000.0).round() as i64)
            .unwrap_or(0);
        Value::pair(Value::I64(day), Value::I64(milli))
    });
    // Per-side sums and lengths only: order-insensitive, so the engine's
    // arrival order and the oracle's agree bit-exactly.
    let join = day_fares.cogroup(&weather, 8).flat_map(|v| {
        let key = v.key().clone();
        let Value::List(sides) = v.val() else { return Vec::new() };
        let stat = |side: &Value| -> (i64, i64) {
            let Value::List(vals) = side else { return (0, 0) };
            (vals.iter().filter_map(Value::as_i64).sum(), vals.len() as i64)
        };
        let (fares, n) = stat(&sides[0]);
        let (precip, _) = stat(&sides[1]);
        vec![Value::pair(key, Value::I64(fares + n * 13 + precip * 7))]
    });

    let gb_s = |r: &crate::exec::QueryReport| {
        r.cost.get(crate::cost::CostCategory::LambdaCompute) / c.pricing.lambda_gb_s
    };
    let lines = s3_lines(&env);
    let mut out = Vec::new();
    for (name, rdd) in [("q1-hour-hist", hist), ("q6j-day-join", join)] {
        let builds0 = env.metrics().get("cache.builds");
        let hits0 = env.metrics().get("cache.hits");
        let cold = sc.run(&rdd, Action::Collect)?;
        let warm = sc.run(&rdd, Action::Collect)?;
        let builds = env.metrics().get("cache.builds") - builds0;
        let hits = env.metrics().get("cache.hits") - hits0;
        ensure!(builds >= 1, "{name}: the cold run must build the cache entry");
        ensure!(hits >= 1, "{name}: the warm re-run must hit the registry");
        // Oracle: a third (also cached) execution against the lineage
        // interpreter — the cache must never change an answer.
        let got = sc.collect(&rdd)?;
        ensure!(
            got == interp::interpret(&rdd, &lines),
            "{name}: the cached plan diverged from the interpreter oracle"
        );
        out.push(CacheAblationRow {
            name,
            cold_s: cold.latency_s,
            warm_s: warm.latency_s,
            cold_gb_s: gb_s(&cold),
            warm_gb_s: gb_s(&warm),
            cold_usd: cold.cost_usd,
            warm_usd: warm.cost_usd,
            builds,
            hits,
        });
    }
    Ok(out)
}

/// A11 companion — the off switch: with `flint.cache.capacity_bytes = 0`
/// (the default), a marker-laden lineage must produce a report and a
/// metrics registry byte-identical to the marker-free lineage in a
/// fresh environment. Modeled clocks only (`compute_scale = 0`): the
/// identity claim is exact, not approximate, so host-measured CPU
/// jitter is excluded from both sides.
pub fn cache_off_identity(cfg: &FlintConfig, trips: u64) -> Result<()> {
    let mut c = cfg.clone();
    c.flint.cache.capacity_bytes = 0;
    c.sim.compute_scale = 0.0;
    let run = |cached: bool| -> Result<(String, Vec<(String, u64)>)> {
        let env = SimEnv::new(c.clone());
        generate_taxi_dataset(&env, "trips", trips);
        let sc = FlintContext::new(env.clone());
        sc.prewarm();
        let scan = sc.text_file(INPUT_BUCKET, "trips/").map(|line| {
            let text = line.as_str().expect("text input");
            let hour = crate::data::schema::TripRecord::parse_csv(text.as_bytes())
                .map(|r| crate::data::chrono::hour_of_day(r.dropoff_ts) as i64)
                .unwrap_or(0);
            Value::pair(Value::I64(hour), Value::I64(1))
        });
        let scan = if cached { scan.cache() } else { scan };
        let rdd = scan
            .reduce_by_key(8, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
        let report = sc.run(&rdd, Action::Collect)?;
        Ok((format!("{report:?}"), env.metrics().snapshot()))
    };
    let (marked, marked_metrics) = run(true)?;
    let (plain, plain_metrics) = run(false)?;
    ensure!(
        marked == plain,
        "cache off must reproduce the marker-free report byte-for-byte:\n{marked}\nvs\n{plain}"
    );
    ensure!(
        marked_metrics == plain_metrics,
        "cache off must leave the metrics registry untouched"
    );
    ensure!(
        marked_metrics.iter().all(|(k, _)| !k.starts_with("cache.")),
        "no cache meters may fire when the cache is off: {marked_metrics:?}"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m1_flint_reads_faster() {
        let (f, s) = s3_throughput(&FlintConfig::default(), 64).unwrap();
        assert!(f > s * 1.5, "boto-class {f:.1} MB/s vs hadoop-class {s:.1} MB/s");
        // Effective rates approach the configured stream rates.
        assert!((20.0..30.0).contains(&f), "{f}");
    }

    #[test]
    fn m2_cold_warm_and_chaining() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let (cold, warm, chained, unchained, links) = cold_warm_chain(&cfg, 20_000).unwrap();
        assert!(cold > warm, "cold {cold:.3} vs warm {warm:.3}");
        assert!(links > 0, "chaining must fire");
        // "The cost of using chained executors is relatively low": under
        // 2x the unchained latency even with an absurdly tight cap.
        assert!(chained < unchained * 3.0, "chained {chained:.3} vs {unchained:.3}");
    }

    #[test]
    fn elasticity_latency_falls_cost_flat() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 256 * 1024;
        cfg.flint.input_split_bytes = 128 * 1024; // many tasks -> waves matter
        let rows = elasticity_sweep(&cfg, 30_000, QueryId::Q1, &[2, 8, 32]).unwrap();
        assert_eq!(rows.len(), 3);
        // Latency strictly improves with concurrency...
        assert!(rows[0].1 > rows[1].1, "{rows:?}");
        assert!(rows[1].1 > rows[2].1, "{rows:?}");
        // ...while cost stays within noise (GB-seconds of work are the
        // same; only wave count changes).
        let (min_c, max_c) = rows
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), (_, _, c)| (lo.min(*c), hi.max(*c)));
        assert!(max_c < min_c * 1.25, "cost must be ~flat: {rows:?}");
    }

    #[test]
    fn a1_shuffle_ablation_covers_the_join_query() {
        // Q6J's exchange-operator join runs through the same ablation
        // harness: sqs (both schedules) + s3 (barrier only).
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let rows = shuffle_ablation(&cfg, 15_000, QueryId::Q6J).unwrap();
        assert_eq!(rows.len(), 3, "{rows:?}");
        assert!(rows.iter().all(|(_, l, c, m)| *l > 0.0 && *c > 0.0 && *m > 0));
        // Pipelined never schedules worse than barrier (serial-fallback
        // guard), even on the join's multi-root DAG.
        assert!(rows[1].1 <= rows[0].1 + 1e-9, "{rows:?}");
    }

    #[test]
    fn a4_straggler_ablation_speculation_strictly_wins() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 256 * 1024;
        let rows =
            straggler_ablation(&cfg, 20_000, &[QueryId::Q1, QueryId::Q5]).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.launches >= 1, "{}: the forced straggler must trigger a backup", r.query);
            assert!(r.wins >= 1, "{}: the clean backup must beat a 10x straggler", r.query);
            assert!(
                r.spec_pipelined_s < r.plain_pipelined_s,
                "{}: speculation {:.3}s must strictly beat plain {:.3}s",
                r.query,
                r.spec_pipelined_s,
                r.plain_pipelined_s
            );
            // (idle_s may legitimately be 0 here: when a queued backup
            // behind long-polling reducers would lose to the serial
            // plan, the scheduler's fallback guard picks serial, which
            // has no long-polling. The dedicated idle-billing test in
            // pipelined_scheduler.rs pins idle metering without
            // speculation in the mix.)
        }
    }

    #[test]
    fn a5_join_crossover_finds_the_flip() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 256 * 1024;
        // Small stage overheads: at test scale the join diamond's two
        // extra stages would otherwise bury the broadcast's read cost.
        cfg.sim.scheduler_overhead_per_stage_s = 0.02;
        cfg.sim.scheduler_overhead_per_task_s = 0.0002;
        let (rows, crossover) =
            join_crossover(&cfg, 15_000, &[0, 32 * 1024 * 1024]).unwrap();
        assert_eq!(rows.len(), 2);
        // Tiny dimension table: broadcast wins (no exchange stage).
        assert!(
            rows[0].broadcast_s < rows[0].shuffle_s,
            "broadcast {:.3}s must win at {} B",
            rows[0].broadcast_s,
            rows[0].dim_bytes
        );
        // Huge dimension table: every map task re-reading it drowns the
        // broadcast; the shuffle join reads it once.
        assert!(
            rows[1].shuffle_s < rows[1].broadcast_s,
            "shuffle {:.3}s must win at {} B (broadcast {:.3}s)",
            rows[1].shuffle_s,
            rows[1].dim_bytes,
            rows[1].broadcast_s
        );
        assert_eq!(crossover, Some(rows[1].dim_bytes));
        assert!(rows[1].dim_bytes >= 32 * 1024 * 1024);
    }

    #[test]
    fn a6_columnar_codec_shrinks_every_shuffle() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let rows =
            codec_byte_ratio(&cfg, 20_000, &[QueryId::Q1, QueryId::Q5, QueryId::Q6J]).unwrap();
        assert_eq!(rows.len(), 3);
        for (q, rows_b, col_b) in rows {
            assert!(rows_b > 0, "{q}: expected shuffle traffic under the rows codec");
            assert!(col_b < rows_b, "{q}: columnar {col_b} B must beat rows {rows_b} B");
        }
    }

    #[test]
    fn a9_sql_optimizer_never_loses() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let rows = sql_optimizer_ablation(&cfg, 10_000).unwrap();
        assert_eq!(rows.len(), QueryId::ALL_WITH_JOINS.len());
        for r in &rows {
            // Harness-level oracle checks already ran; here pin the
            // ablation's claim: the optimizer never makes a query
            // slower (small tolerance for schedule jitter).
            assert!(
                r.on_latency_s <= r.off_latency_s * 1.02 + 1e-6,
                "{}: optimizer-on {:.3}s lost to off {:.3}s",
                r.query,
                r.on_latency_s,
                r.off_latency_s
            );
        }
        // The joins got a strategy; the scans did not.
        let q6 = rows.iter().find(|r| r.query == QueryId::Q6).unwrap();
        assert_eq!(q6.join_strategy, Some("broadcast"), "tiny weather table must broadcast");
        let q6j = rows.iter().find(|r| r.query == QueryId::Q6J).unwrap();
        assert_eq!(q6j.join_strategy, Some("shuffle"), "threshold 0 must force the shuffle");
        assert!(rows.iter().filter(|r| r.join_strategy.is_none()).count() >= 6);
        // Q6 under the broadcast plan must strictly beat the forced
        // shuffle plan (same SQL text, same data): the CBO's pick pays.
        assert!(
            q6.on_latency_s < q6j.on_latency_s,
            "broadcast Q6 {:.3}s vs forced-shuffle Q6J {:.3}s",
            q6.on_latency_s,
            q6j.on_latency_s
        );
    }

    #[test]
    fn a9_cost_model_agrees_with_measured_crossover() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 256 * 1024;
        // Same shape as the A5 test: small stage overheads so the
        // broadcast's read cost isn't buried at test scale.
        cfg.sim.scheduler_overhead_per_stage_s = 0.02;
        cfg.sim.scheduler_overhead_per_task_s = 0.0002;
        let rows = sql_cbo_agreement(&cfg, 15_000, &[0, 32 * 1024 * 1024]).unwrap();
        assert_eq!(rows.len(), 2);
        for (dim_bytes, measured, planned) in &rows {
            assert_eq!(
                measured, planned,
                "at {dim_bytes} B dim the planner picked {planned:?} but {measured:?} won"
            );
        }
        // And the two sides of the crossover really differ.
        assert_eq!(rows[0].1, JoinStrategy::Broadcast);
        assert_eq!(rows[1].1, JoinStrategy::Shuffle);
    }

    #[test]
    fn a7_pruning_skips_gets_and_preserves_results() {
        let mut cfg = FlintConfig::for_tests();
        // Many small objects: the day-window stats tile the timeline
        // across them, so a narrow window leaves most splits prunable.
        cfg.data.object_bytes = 256 * 1024;
        cfg.flint.input_split_bytes = 256 * 1024;
        let (pruned, unpruned, skipped) = pruning_ablation(&cfg, 30_000, 0, 200).unwrap();
        assert!(skipped > 0, "a narrow day window must prune splits");
        assert!(
            pruned < unpruned,
            "pruned run must issue fewer GETs: {pruned} vs {unpruned} ({skipped} skipped)"
        );
    }

    #[test]
    fn a6_fair_beats_fifo_tail_without_throughput_loss() {
        let mut cfg = FlintConfig::for_tests();
        // 4 scan + 4 reduce tasks per query on 8 slots, fully modeled
        // durations: arbitration alone decides the tail.
        cfg.data.object_bytes = 128 * 1024;
        cfg.flint.input_split_bytes = 128 * 1024;
        cfg.sim.compute_scale = 0.0;
        let rows = concurrency_ablation(
            &cfg,
            5_000,
            &[4],
            &[ServicePolicy::Fifo, ServicePolicy::Fair],
        )
        .unwrap();
        assert_eq!(rows.len(), 2, "{rows:?}");
        let (fifo, fair) = (&rows[0], &rows[1]);
        assert_eq!(fifo.policy, ServicePolicy::Fifo);
        assert!(
            fair.p99_s < fifo.p99_s,
            "fair p99 {:.3} vs fifo p99 {:.3}",
            fair.p99_s,
            fifo.p99_s
        );
        assert!(
            fair.throughput_qps >= fifo.throughput_qps - 1e-9,
            "fair {:.4} q/s vs fifo {:.4} q/s",
            fair.throughput_qps,
            fifo.throughput_qps
        );
    }

    #[test]
    fn a1_shuffle_backends_both_work_and_differ() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let rows = shuffle_ablation(&cfg, 20_000, QueryId::Q5).unwrap();
        assert_eq!(rows.len(), 3, "sqs x2 schedules + s3 barrier: {rows:?}");
        assert!(rows.iter().all(|(_, l, c, m)| *l > 0.0 && *c > 0.0 && *m > 0));
        let sqs_barrier = &rows[0];
        let sqs_pipelined = &rows[1];
        let s3_barrier = &rows[2];
        // S3 shuffle pays per-object first-byte latency on both sides:
        // slower for this many-small-groups query (the paper's intuition
        // that "the I/O patterns are not a good fit for S3").
        assert!(
            s3_barrier.1 > sqs_barrier.1,
            "s3 {:.3}s vs sqs {:.3}s",
            s3_barrier.1,
            sqs_barrier.1
        );
        // Pipelining the SQS shuffle hides reduce drain behind map
        // flushes: strictly lower than the barrier clock on the same run.
        assert!(
            sqs_pipelined.1 < sqs_barrier.1,
            "pipelined {:.3}s vs barrier {:.3}s",
            sqs_pipelined.1,
            sqs_barrier.1
        );
    }

    #[test]
    fn a10_tree_exchange_wins_requests_and_wall_at_scale() {
        let cfg = FlintConfig::for_tests();
        let rows = exchange_sweep(&cfg, &[(8, 8), (32, 1024)]).unwrap();
        assert_eq!(rows.len(), 2);
        // Drained-stream equality is enforced inside the harness; here
        // pin the headline claim: at a 1024-way fan-out the merge level
        // pays for itself in both request count and wall clock.
        let big = &rows[1];
        assert_eq!((big.producers, big.partitions), (32, 1024));
        assert!(
            big.tree_requests < big.direct_requests,
            "tree {} requests must undercut direct {} at 32x1024",
            big.tree_requests,
            big.direct_requests
        );
        assert!(
            big.tree_wall_s < big.direct_wall_s,
            "tree wall {:.3}s must undercut direct {:.3}s at 32x1024",
            big.tree_wall_s,
            big.direct_wall_s
        );
        assert!(rows[0].direct_requests > 0 && rows[0].tree_requests > 0);
    }

    #[test]
    fn a11_warm_rerun_wins_both_axes() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 256 * 1024;
        cfg.flint.input_split_bytes = 256 * 1024;
        // Modeled clocks: the warm-beats-cold gate is exact, not subject
        // to host CPU jitter.
        cfg.sim.compute_scale = 0.0;
        let rows = cache_ablation(&cfg, 20_000).unwrap();
        assert_eq!(rows.len(), 2, "{rows:?}");
        for r in &rows {
            assert!(r.builds >= 1 && r.hits >= 1, "{r:?}");
            assert!(
                r.warm_s < r.cold_s,
                "{}: warm {:.3}s must beat cold {:.3}s",
                r.name,
                r.warm_s,
                r.cold_s
            );
            assert!(
                r.warm_gb_s < r.cold_gb_s,
                "{}: warm {:.4} GB-s must beat cold {:.4} GB-s",
                r.name,
                r.warm_gb_s,
                r.cold_gb_s
            );
        }
    }

    #[test]
    fn a11_cache_off_identity_holds() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 256 * 1024;
        cfg.flint.input_split_bytes = 256 * 1024;
        cache_off_identity(&cfg, 10_000).unwrap();
    }

    #[test]
    fn a10_auto_backend_never_loses() {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let rows = backend_auto_ablation(&cfg, 15_000, &[QueryId::Q1, QueryId::Q6J]).unwrap();
        assert_eq!(rows.len(), 2);
        for (q, sqs, s3, auto) in rows {
            assert!(
                auto <= sqs.min(s3) * 1.02 + 1e-6,
                "{q}: auto {auto:.3}s lost to sqs {sqs:.3}s / s3 {s3:.3}s"
            );
        }
    }
}
