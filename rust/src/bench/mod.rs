//! Benchmark harness (DESIGN.md §6): regenerates every quantitative
//! artifact of the paper's evaluation.
//!
//! * [`table1`] — the headline table: latency + cost for Q0–Q6 across
//!   Flint / PySpark / Spark, in two modes: **measured** (the simulated
//!   stack on generated data) and **paper** (analytic extrapolation to
//!   the 215 GB / 1.3 B-trip workload, DESIGN.md §5).
//! * [`micro`] — the §IV in-text microbenchmarks: S3 read throughput
//!   (boto vs Hadoop), cold vs warm starts, chaining overhead, and the
//!   SQS-vs-S3 shuffle ablation from §VI.

pub mod micro;
pub mod paper;
pub mod table1;

pub use table1::{run_table1, Table1Options, Table1Row};
