//! Paper-scale extrapolation: analytic latency/cost for the 215 GiB /
//! 1.3 B-trip NYC-taxi workload, built from (a) the config's calibrated
//! service models and (b) *measured* per-row compute rates from a real
//! run of the simulated stack.
//!
//! What's measured vs modeled (DESIGN.md §5):
//! * per-row executor compute comes from the measured run, scaled by
//!   [`PAPER_PY_COMPUTE_SCALE`] to stand in for the paper's CPython
//!   executors (ours are Rust+PJRT, ~25× faster per row);
//! * S3 stream throughput, cold/warm starts, SQS round trips, pricing
//!   are the calibrated config constants;
//! * stage makespan is the same K-slot wave model the simulator uses.

use crate::compute::queries::QueryId;
use crate::config::FlintConfig;
use crate::data::Dataset;
use crate::exec::QueryReport;
use crate::simtime::Component;

/// Ratio of the paper's CPython executor cost-per-row to this repo's
/// Rust+PJRT executors (measured Rust parse+kernel ≈ 0.2 µs/row; Python
/// split+filter+dict work in 2018 ≈ 5 µs/row). Flint's executors and
/// PySpark's UDF workers are CPython; Scala Spark is JVM (~2× Rust).
pub const PAPER_PY_COMPUTE_SCALE: f64 = 25.0;
pub const PAPER_JVM_COMPUTE_SCALE: f64 = 2.0;

/// The paper-scale split size (Hadoop default, 64 MiB) — independent of
/// whatever small splits the measured run used.
pub const PAPER_SPLIT_BYTES: f64 = 64.0 * 1024.0 * 1024.0;

/// The paper's concurrency: 80 Lambda invocations matched to 80 vCores.
pub const PAPER_SLOTS: f64 = 80.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaperEngine {
    Flint,
    PySpark,
    Spark,
}

/// Estimate `(latency_s, cost_usd)` for one query at paper scale.
pub fn estimate(
    query: QueryId,
    measured: &QueryReport,
    cfg: &FlintConfig,
    dataset: &Dataset,
    engine: PaperEngine,
) -> (f64, f64) {
    let sim = &cfg.sim;
    let total_bytes = cfg.data.paper_total_bytes as f64;
    let total_rows = cfg.data.paper_total_trips as f64;
    let split = PAPER_SPLIT_BYTES;
    let n_map = (total_bytes / split).ceil();
    let rows_per_task = total_rows / n_map;
    // The paper's experimental setup, not the measured run's (tests use
    // tiny concurrency for speed; the estimate is always for the paper).
    let slots = PAPER_SLOTS;

    // Measured compute per row (real Rust work), re-scaled to the
    // paper's executors: CPython for Flint and PySpark UDF workers,
    // JVM for Scala Spark.
    let compute_scale = match engine {
        PaperEngine::Flint | PaperEngine::PySpark => PAPER_PY_COMPUTE_SCALE,
        PaperEngine::Spark => PAPER_JVM_COMPUTE_SCALE,
    };
    let measured_rows = measured.timeline.get(Component::Compute).max(1e-9);
    let compute_per_row = measured_rows / (dataset.trips.max(1) as f64) * compute_scale;

    let mbps = match engine {
        PaperEngine::Flint => sim.s3_flint_mbps,
        _ => sim.s3_spark_mbps,
    };
    let read_s = sim.s3_first_byte_s + split / (mbps * 1e6);
    let mut map_task_s = read_s + rows_per_task * compute_per_row;
    if engine == PaperEngine::PySpark {
        map_task_s += rows_per_task * sim.pyspark_pipe_per_record_s;
    }
    if engine == PaperEngine::Flint {
        map_task_s += sim.lambda_warm_start_s + 0.002;
    }

    // Shuffle sends: measured messages per map task carry over (bucket
    // counts don't depend on scale, message bodies are tiny).
    let spec = query.spec();
    let msgs_per_map = if spec.reduce_partitions > 0 {
        (measured.shuffle_msgs as f64 / 2.0 / measured.tasks.max(1) as f64).max(1.0)
    } else {
        0.0
    };
    let mut chains = 0.0;
    match engine {
        PaperEngine::Flint => {
            map_task_s += msgs_per_map * sim.sqs_rtt_s;
            // Executor chaining if a task exceeds the duration cap.
            let cap = sim.lambda_time_limit_s - sim.lambda_chain_margin_s;
            if map_task_s > cap {
                chains = (map_task_s / cap).ceil() - 1.0;
                map_task_s += chains * (sim.lambda_warm_start_s + 0.002);
            }
        }
        _ => {
            map_task_s += msgs_per_map * (24.0 * 1024.0) / (sim.cluster_shuffle_mbps * 1e6);
        }
    }

    // Map stage: waves over the concurrency slots + driver overhead.
    let waves = (n_map / slots).ceil();
    let map_stage_s = waves * map_task_s
        + sim.scheduler_overhead_per_stage_s
        + n_map * sim.scheduler_overhead_per_task_s;

    // Reduce stage (when the query shuffles).
    let mut reduce_stage_s = 0.0;
    let mut reduce_task_s = 0.0;
    let n_reduce = spec.reduce_partitions as f64;
    if spec.reduce_partitions > 0 {
        let msgs_total = n_map * msgs_per_map;
        let msgs_per_part = msgs_total / n_reduce;
        reduce_task_s = match engine {
            PaperEngine::Flint => {
                // receive batches of 10 + empty poll + delete batches.
                let receives = (msgs_per_part / 10.0).ceil() + 1.0;
                let deletes = (msgs_per_part / 10.0).ceil();
                sim.lambda_warm_start_s + 0.002 + (receives + deletes) * sim.sqs_rtt_s
            }
            _ => 0.01,
        };
        let rwaves = (n_reduce / slots).ceil();
        reduce_stage_s = rwaves * reduce_task_s
            + sim.scheduler_overhead_per_stage_s
            + n_reduce * sim.scheduler_overhead_per_task_s;
    }

    let latency = map_stage_s + reduce_stage_s;

    // Cost.
    let cost = match engine {
        PaperEngine::Flint => {
            let gb = sim.lambda_memory_mb as f64 / 1024.0;
            let billed_map = n_map * (map_task_s - sim.lambda_warm_start_s).max(0.1);
            let billed_reduce = n_reduce * reduce_task_s;
            let invocations = n_map * (1.0 + chains) + n_reduce;
            let lambda_usd = (billed_map + billed_reduce) * gb * cfg.pricing.lambda_gb_s
                + invocations * cfg.pricing.lambda_per_request;
            // SQS: sends + receives + deletes, one billed request per
            // 64 KB chunk (bodies are small: 1 chunk each).
            let sqs_requests = n_map * msgs_per_map
                + if spec.reduce_partitions > 0 {
                    2.0 * n_map * msgs_per_map / 10.0 + n_reduce
                } else {
                    0.0
                };
            let sqs_usd = sqs_requests * cfg.pricing.sqs_per_million_requests / 1e6;
            let s3_usd = n_map * cfg.pricing.s3_get_per_1000 / 1000.0;
            lambda_usd + sqs_usd + s3_usd
        }
        _ => latency * cfg.pricing.cluster_per_hour / 3600.0,
    };
    (latency, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::table1::{run_table1, Table1Options};

    fn rows() -> Vec<crate::bench::table1::Table1Row> {
        let mut cfg = FlintConfig::for_tests();
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        let opts = Table1Options {
            trips: 20_000,
            trials_flint: 1,
            trials_cluster: 1,
            queries: QueryId::ALL.to_vec(),
            paper_scale: true,
        };
        run_table1(&cfg, &opts).unwrap().1
    }

    #[test]
    fn paper_estimates_reproduce_table1_shape() {
        let rows = rows();
        for row in &rows {
            let est = row.paper_estimate.as_ref().unwrap();
            let (flint, pyspark, spark) = (est[0], est[1], est[2]);
            // Finding 1: Spark latency roughly flat around ~190 s. The
            // estimator folds in *measured* host compute, so debug builds
            // (several times slower, worse under parallel-test
            // contention) get wide bounds; release is held tight.
            let spark_hi = if cfg!(debug_assertions) { 500.0 } else { 260.0 };
            assert!(
                (150.0..spark_hi).contains(&spark.0),
                "{}: spark {:.0}s",
                row.query,
                spark.0
            );
            // Finding 2+3: Flint < PySpark on every query.
            assert!(
                flint.0 < pyspark.0,
                "{}: flint {:.0} !< pyspark {:.0}",
                row.query,
                flint.0,
                pyspark.0
            );
            // PySpark > Spark.
            assert!(pyspark.0 > spark.0, "{}", row.query);
            // Costs: cluster engines track latency; Flint pays the Lambda
            // premium (bounded, not free; loose for debug builds where
            // billed GB-seconds inflate with the slower measured compute).
            let cost_ratio = if cfg!(debug_assertions) { 15.0 } else { 6.0 };
            assert!(
                flint.1 > 0.05 && flint.1 < cost_ratio * spark.1,
                "{}: ${:.2}",
                row.query,
                flint.1
            );
        }
        // Finding (Q0): Flint beats Spark on the read-bound query. The
        // inequality depends on realistic (release-build) per-row rates:
        // under debug builds the measured Rust compute is ~10× slower and
        // the ×25 CPython scaling swamps Flint's read advantage, so the
        // release-mode bench (`cargo bench --bench table1`) is the
        // authoritative check.
        let q0 = rows.iter().find(|r| r.query == QueryId::Q0).unwrap();
        let est = q0.paper_estimate.as_ref().unwrap();
        if !cfg!(debug_assertions) {
            assert!(est[0].0 < est[2].0, "flint Q0 {:.0} vs spark {:.0}", est[0].0, est[2].0);
            assert!((60.0..160.0).contains(&est[0].0), "flint Q0 {:.0}s", est[0].0);
        }
    }

    #[test]
    fn shuffle_queries_cost_more_than_q0_for_flint() {
        let rows = rows();
        let q0_cost = rows[0].paper_estimate.as_ref().unwrap()[0].1;
        for row in &rows[1..] {
            let c = row.paper_estimate.as_ref().unwrap()[0].1;
            assert!(
                c >= q0_cost * 0.9,
                "{}: shuffle can't be cheaper than map-only ({c:.2} vs {q0_cost:.2})",
                row.query
            );
        }
    }
}
