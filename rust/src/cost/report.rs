//! Cost/latency report formatting shared by examples and benches —
//! renders rows in the paper's Table I style — plus the per-tenant
//! [`CostLedger`] the multi-tenant service bills into.

use crate::cost::CostSnapshot;
use crate::util::stats::Summary;
use std::collections::BTreeMap;

/// One tenant's bill for a service lifetime: every dollar a tenant's
/// queries spend — Lambda GB-seconds, per-request charges, SQS/S3
/// requests, long-poll idle — accumulated as exact [`CostSnapshot`]
/// diffs around each query, so the sum over all ledgers equals the
/// pool's total billed spend to the last floating-point bit.
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    /// Queries this tenant completed.
    pub queries: u64,
    /// Σ GB-seconds across the tenant's attempts (productive compute).
    pub gb_seconds: f64,
    /// Occupied-but-idle seconds billed to long-polling consumers on
    /// the shared clock.
    pub idle_s: f64,
    /// Speculative backup attempts launched for this tenant's queries.
    pub speculative_launches: u64,
    /// Exact USD breakdown (category-wise sum of per-query diffs).
    pub cost: CostSnapshot,
}

impl CostLedger {
    pub fn total_usd(&self) -> f64 {
        self.cost.total()
    }
}

/// Render per-tenant ledgers as a small markdown table, tenants in
/// lexicographic order (deterministic output for diffs and CI logs).
pub fn render_ledgers(ledgers: &BTreeMap<String, CostLedger>) -> String {
    let mut out = String::new();
    out.push_str("| tenant | queries | GB-s | idle (s) | backups | cost (USD) |\n");
    out.push_str("|---|---|---|---|---|---|\n");
    for (tenant, l) in ledgers {
        out.push_str(&format!(
            "| {tenant} | {} | {:.2} | {:.2} | {} | {:.6} |\n",
            l.queries, l.gb_seconds, l.idle_s, l.speculative_launches, l.total_usd()
        ));
    }
    out
}

/// One engine's result for one query.
#[derive(Debug, Clone)]
pub struct Cell {
    /// Latency trials in seconds.
    pub latency: Summary,
    /// Cost trials in USD (mean reported, like the paper).
    pub cost: Summary,
    /// Cost breakdown from the last trial, for the detailed report.
    pub cost_detail: CostSnapshot,
}

/// Render Table I: one row per query, engines across.
pub fn render_table1(
    title: &str,
    engines: &[&str],
    rows: &[(String, Vec<Cell>)],
    show_ci: bool,
) -> String {
    let mut out = String::new();
    out.push_str(&format!("## {title}\n\n"));
    out.push_str("|   | Query Latency (s) |");
    for _ in 1..engines.len() {
        out.push_str("   |");
    }
    out.push_str(" Estimated Cost (USD) |");
    for _ in 1..engines.len() {
        out.push_str("   |");
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in 0..engines.len() * 2 {
        out.push_str("---|");
    }
    out.push('\n');
    out.push_str("|   |");
    for e in engines {
        out.push_str(&format!(" {e} |"));
    }
    for e in engines {
        out.push_str(&format!(" {e} |"));
    }
    out.push('\n');
    for (name, cells) in rows {
        out.push_str(&format!("| {name} |"));
        for (i, cell) in cells.iter().enumerate() {
            // Paper convention: CI shown for Flint (col 0), mean only for
            // the low-variance cluster engines.
            if show_ci && i == 0 && cell.latency.n > 1 {
                out.push_str(&format!(" {} |", cell.latency.fmt_ci(1.0)));
            } else if cell.latency.mean < 10.0 {
                out.push_str(&format!(" {:.2} |", cell.latency.mean));
            } else {
                out.push_str(&format!(" {:.0} |", cell.latency.mean));
            }
        }
        for cell in cells {
            if cell.cost.mean < 0.01 {
                out.push_str(&format!(" {:.4} |", cell.cost.mean));
            } else {
                out.push_str(&format!(" {:.2} |", cell.cost.mean));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(lat: &[f64], cost: f64) -> Cell {
        Cell {
            latency: Summary::of(lat),
            cost: Summary::of(&[cost]),
            cost_detail: CostSnapshot::default(),
        }
    }

    #[test]
    fn renders_paper_shape() {
        let rows = vec![
            ("0".to_string(), vec![cell(&[101.0, 99.0, 103.0], 0.20), cell(&[211.0], 0.41), cell(&[188.0], 0.37)]),
            ("1".to_string(), vec![cell(&[190.0], 0.59), cell(&[316.0], 0.61), cell(&[189.0], 0.37)]),
        ];
        let table = render_table1("Table I", &["Flint", "PySpark", "Spark"], &rows, true);
        assert!(table.contains("| 0 |"), "{table}");
        assert!(table.contains("101 ["), "CI for Flint: {table}");
        assert!(table.contains("| 211 |"), "{table}");
        assert!(table.contains("0.20"), "{table}");
    }
}
