//! USD cost accounting — the second column of the paper's Table I.
//!
//! Every simulated service charges into a shared [`CostTracker`] under a
//! [`CostCategory`]; the per-engine totals become the "Estimated Cost"
//! column. Pricing constants live in [`crate::config::Pricing`].

pub mod report;

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Mutex;

/// Cost buckets, mirroring the paper's accounting: Lambda GB-seconds +
/// requests and SQS requests for Flint; instance-hours for the cluster;
/// S3 requests for both.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CostCategory {
    LambdaCompute,
    LambdaRequests,
    SqsRequests,
    S3Requests,
    ClusterTime,
}

impl CostCategory {
    pub const ALL: [CostCategory; 5] = [
        CostCategory::LambdaCompute,
        CostCategory::LambdaRequests,
        CostCategory::SqsRequests,
        CostCategory::S3Requests,
        CostCategory::ClusterTime,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            CostCategory::LambdaCompute => "lambda_compute",
            CostCategory::LambdaRequests => "lambda_requests",
            CostCategory::SqsRequests => "sqs_requests",
            CostCategory::S3Requests => "s3_requests",
            CostCategory::ClusterTime => "cluster_time",
        }
    }
}

/// Thread-safe accumulating cost ledger.
#[derive(Debug, Default)]
pub struct CostTracker {
    usd: Mutex<BTreeMap<CostCategory, f64>>,
}

impl CostTracker {
    pub fn new() -> CostTracker {
        CostTracker::default()
    }

    /// Add `usd` dollars under `category`.
    pub fn charge(&self, category: CostCategory, usd: f64) {
        debug_assert!(usd >= 0.0, "negative charge {usd}");
        if usd > 0.0 {
            let mut book = self.usd.lock().expect("cost book poisoned");
            *book.entry(category).or_insert(0.0) += usd;
        }
    }

    /// Total across all categories.
    pub fn total(&self) -> f64 {
        self.usd.lock().expect("cost book poisoned").values().sum()
    }

    pub fn get(&self, category: CostCategory) -> f64 {
        self.usd
            .lock()
            .expect("cost book poisoned")
            .get(&category)
            .copied()
            .unwrap_or(0.0)
    }

    /// Snapshot of all non-zero categories.
    pub fn snapshot(&self) -> CostSnapshot {
        CostSnapshot { usd: self.usd.lock().expect("cost book poisoned").clone() }
    }

    /// Zero the ledger (between bench trials).
    pub fn reset(&self) {
        self.usd.lock().expect("cost book poisoned").clear();
    }
}

/// An immutable point-in-time copy of the ledger, subtractable so a trial
/// can be costed as `after - before`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostSnapshot {
    usd: BTreeMap<CostCategory, f64>,
}

impl CostSnapshot {
    pub fn total(&self) -> f64 {
        self.usd.values().sum()
    }

    pub fn get(&self, category: CostCategory) -> f64 {
        self.usd.get(&category).copied().unwrap_or(0.0)
    }

    /// Component-wise `self += other` — how per-query diffs accumulate
    /// into a tenant's [`report::CostLedger`].
    pub fn add(&mut self, other: &CostSnapshot) {
        for (cat, v) in &other.usd {
            *self.usd.entry(*cat).or_insert(0.0) += v;
        }
    }

    /// Component-wise `self - earlier` (clamped at 0).
    pub fn since(&self, earlier: &CostSnapshot) -> CostSnapshot {
        let mut usd = BTreeMap::new();
        for cat in CostCategory::ALL {
            let d = self.get(cat) - earlier.get(cat);
            if d > 0.0 {
                usd.insert(cat, d);
            }
        }
        CostSnapshot { usd }
    }

    pub fn breakdown(&self) -> Vec<(CostCategory, f64)> {
        self.usd.iter().map(|(c, v)| (*c, *v)).collect()
    }
}

impl fmt::Display for CostSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "${:.4} [", self.total())?;
        for (i, (c, v)) in self.breakdown().iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{}=${:.4}", c.name(), v)?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let t = CostTracker::new();
        t.charge(CostCategory::LambdaCompute, 0.10);
        t.charge(CostCategory::LambdaCompute, 0.05);
        t.charge(CostCategory::SqsRequests, 0.01);
        assert!((t.total() - 0.16).abs() < 1e-12);
        assert!((t.get(CostCategory::LambdaCompute) - 0.15).abs() < 1e-12);
    }

    #[test]
    fn snapshot_diff() {
        let t = CostTracker::new();
        t.charge(CostCategory::S3Requests, 0.02);
        let before = t.snapshot();
        t.charge(CostCategory::S3Requests, 0.03);
        t.charge(CostCategory::ClusterTime, 0.50);
        let delta = t.snapshot().since(&before);
        assert!((delta.get(CostCategory::S3Requests) - 0.03).abs() < 1e-12);
        assert!((delta.get(CostCategory::ClusterTime) - 0.50).abs() < 1e-12);
        assert!((delta.total() - 0.53).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let t = CostTracker::new();
        t.charge(CostCategory::ClusterTime, 1.0);
        t.reset();
        assert_eq!(t.total(), 0.0);
    }

    #[test]
    fn concurrent_charges() {
        let t = std::sync::Arc::new(CostTracker::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let t = std::sync::Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    t.charge(CostCategory::SqsRequests, 0.001);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!((t.total() - 8.0).abs() < 1e-9);
    }
}
