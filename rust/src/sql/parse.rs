//! Recursive-descent parser for the supported SQL subset:
//!
//! ```text
//! [EXPLAIN] SELECT item [, item]*
//!   FROM table [alias]
//!   [JOIN table [alias] ON expr]
//!   [WHERE expr]
//!   [GROUP BY expr [, expr]*]
//!   [HAVING expr]
//!   [ORDER BY expr [ASC|DESC] [, ...]]
//!   [LIMIT n]
//! ```
//!
//! Expressions: column refs (optionally `alias.`-qualified), numeric
//! literals, string literals, `+ - * /`, comparisons (`= != <> < <= >
//! >=`), `BETWEEN a AND b`, `AND`/`OR`/`NOT`, parentheses, and the
//! aggregates `COUNT(*) | COUNT(e) | SUM | AVG | MIN | MAX`.
//!
//! Every AST node keeps the byte offset of the token that produced it,
//! so semantic errors downstream point into the query text.

use crate::sql::lex::{lex, SqlError, Sym, Tok, Token};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Eq,
    NotEq,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

impl BinOp {
    pub fn text(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Eq => "=",
            BinOp::NotEq => "!=",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::And => "AND",
            BinOp::Or => "OR",
        }
    }

    pub fn is_comparison(self) -> bool {
        matches!(self, BinOp::Eq | BinOp::NotEq | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggFunc {
    Count,
    Sum,
    Avg,
    Min,
    Max,
}

impl AggFunc {
    pub fn name(self) -> &'static str {
        match self {
            AggFunc::Count => "count",
            AggFunc::Sum => "sum",
            AggFunc::Avg => "avg",
            AggFunc::Min => "min",
            AggFunc::Max => "max",
        }
    }

    fn from_ident(s: &str) -> Option<AggFunc> {
        match s.to_ascii_lowercase().as_str() {
            "count" => Some(AggFunc::Count),
            "sum" => Some(AggFunc::Sum),
            "avg" => Some(AggFunc::Avg),
            "min" => Some(AggFunc::Min),
            "max" => Some(AggFunc::Max),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Column { table: Option<String>, name: String, offset: usize },
    Number { value: f64, offset: usize },
    Str { value: String, offset: usize },
    Neg { expr: Box<Expr>, offset: usize },
    Not { expr: Box<Expr>, offset: usize },
    Binary { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr>, offset: usize },
    Between { expr: Box<Expr>, lo: Box<Expr>, hi: Box<Expr>, offset: usize },
    /// `COUNT(*)` carries `arg: None`.
    Agg { func: AggFunc, arg: Option<Box<Expr>>, offset: usize },
}

impl Expr {
    pub fn offset(&self) -> usize {
        match self {
            Expr::Column { offset, .. }
            | Expr::Number { offset, .. }
            | Expr::Str { offset, .. }
            | Expr::Neg { offset, .. }
            | Expr::Not { offset, .. }
            | Expr::Binary { offset, .. }
            | Expr::Between { offset, .. }
            | Expr::Agg { offset, .. } => *offset,
        }
    }

    /// Does any aggregate call appear in this expression?
    pub fn has_agg(&self) -> bool {
        match self {
            Expr::Agg { .. } => true,
            Expr::Column { .. } | Expr::Number { .. } | Expr::Str { .. } => false,
            Expr::Neg { expr, .. } | Expr::Not { expr, .. } => expr.has_agg(),
            Expr::Binary { lhs, rhs, .. } => lhs.has_agg() || rhs.has_agg(),
            Expr::Between { expr, lo, hi, .. } => {
                expr.has_agg() || lo.has_agg() || hi.has_agg()
            }
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum SelectItem {
    /// `SELECT *` — expands to every column of the FROM (and JOIN) table.
    Star { offset: usize },
    Expr { expr: Expr, alias: Option<String> },
}

#[derive(Debug, Clone, PartialEq)]
pub struct TableRef {
    pub name: String,
    pub alias: Option<String>,
    pub offset: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct JoinClause {
    pub table: TableRef,
    pub on: Expr,
    pub offset: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct OrderItem {
    pub expr: Expr,
    pub desc: bool,
}

#[derive(Debug, Clone, PartialEq)]
pub struct SelectQuery {
    pub items: Vec<SelectItem>,
    pub from: TableRef,
    pub join: Option<JoinClause>,
    pub where_clause: Option<Expr>,
    pub group_by: Vec<Expr>,
    pub having: Option<Expr>,
    pub order_by: Vec<OrderItem>,
    pub limit: Option<usize>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Statement {
    /// `EXPLAIN SELECT …` — render plans instead of executing.
    pub explain: bool,
    pub query: SelectQuery,
}

/// Parse one statement (an optional trailing `;` is accepted).
pub fn parse(text: &str) -> Result<Statement, SqlError> {
    let tokens = lex(text)?;
    let mut p = Parser { tokens, pos: 0, eof: text.len() };
    let explain = p.eat_kw("EXPLAIN");
    p.expect_kw("SELECT")?;
    let query = p.select_body()?;
    p.eat_sym(Sym::Semi);
    if let Some(t) = p.peek() {
        return Err(SqlError::new(format!("unexpected {} after statement", t.describe()), t.offset));
    }
    Ok(Statement { explain, query })
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
    /// Offset reported for errors at end of input.
    eof: usize,
}

/// Identifiers that end an expression list — never column names.
const CLAUSE_KWS: &[&str] =
    &["FROM", "JOIN", "INNER", "ON", "WHERE", "GROUP", "HAVING", "ORDER", "LIMIT", "BY", "AS", "ASC", "DESC", "AND", "OR", "NOT", "BETWEEN", "SELECT", "EXPLAIN"];

impl Parser {
    fn peek(&self) -> Option<&Token> {
        self.tokens.get(self.pos)
    }

    fn next(&mut self) -> Option<Token> {
        let t = self.tokens.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn here(&self) -> usize {
        self.peek().map_or(self.eof, |t| t.offset)
    }

    fn err_here(&self, want: &str) -> SqlError {
        match self.peek() {
            Some(t) => SqlError::new(format!("expected {want}, found {}", t.describe()), t.offset),
            None => SqlError::new(format!("expected {want}, found end of query"), self.eof),
        }
    }

    fn eat_kw(&mut self, kw: &str) -> bool {
        if self.peek().is_some_and(|t| t.is_kw(kw)) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_kw(&mut self, kw: &str) -> Result<(), SqlError> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            Err(self.err_here(&format!("`{kw}`")))
        }
    }

    fn eat_sym(&mut self, sym: Sym) -> bool {
        if self.peek().is_some_and(|t| t.tok == Tok::Sym(sym)) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_sym(&mut self, sym: Sym) -> Result<(), SqlError> {
        if self.eat_sym(sym) {
            Ok(())
        } else {
            Err(self.err_here(&format!("`{}`", sym.text())))
        }
    }

    /// A non-keyword identifier (column/table/alias name).
    fn ident(&mut self, what: &str) -> Result<(String, usize), SqlError> {
        match self.peek() {
            Some(Token { tok: Tok::Ident(s), offset })
                if !CLAUSE_KWS.iter().any(|k| s.eq_ignore_ascii_case(k)) =>
            {
                let out = (s.clone(), *offset);
                self.pos += 1;
                Ok(out)
            }
            _ => Err(self.err_here(what)),
        }
    }

    fn select_body(&mut self) -> Result<SelectQuery, SqlError> {
        let mut items = vec![self.select_item()?];
        while self.eat_sym(Sym::Comma) {
            items.push(self.select_item()?);
        }
        self.expect_kw("FROM")?;
        let from = self.table_ref()?;
        let join = if self.peek().is_some_and(|t| t.is_kw("JOIN") || t.is_kw("INNER")) {
            let offset = self.here();
            self.eat_kw("INNER");
            self.expect_kw("JOIN")?;
            let table = self.table_ref()?;
            self.expect_kw("ON")?;
            let on = self.expr()?;
            Some(JoinClause { table, on, offset })
        } else {
            None
        };
        let where_clause = if self.eat_kw("WHERE") { Some(self.expr()?) } else { None };
        let mut group_by = Vec::new();
        if self.eat_kw("GROUP") {
            self.expect_kw("BY")?;
            group_by.push(self.expr()?);
            while self.eat_sym(Sym::Comma) {
                group_by.push(self.expr()?);
            }
        }
        let having = if self.eat_kw("HAVING") { Some(self.expr()?) } else { None };
        let mut order_by = Vec::new();
        if self.eat_kw("ORDER") {
            self.expect_kw("BY")?;
            loop {
                let expr = self.expr()?;
                let desc = self.eat_kw("DESC");
                if !desc {
                    self.eat_kw("ASC");
                }
                order_by.push(OrderItem { expr, desc });
                if !self.eat_sym(Sym::Comma) {
                    break;
                }
            }
        }
        let limit = if self.eat_kw("LIMIT") {
            let off = self.here();
            match self.next() {
                Some(Token { tok: Tok::Number(n), .. })
                    if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 =>
                {
                    Some(n as usize)
                }
                _ => return Err(SqlError::new("LIMIT takes a non-negative integer", off)),
            }
        } else {
            None
        };
        Ok(SelectQuery { items, from, join, where_clause, group_by, having, order_by, limit })
    }

    fn select_item(&mut self) -> Result<SelectItem, SqlError> {
        if let Some(Token { tok: Tok::Sym(Sym::Star), offset }) = self.peek() {
            let offset = *offset;
            self.pos += 1;
            return Ok(SelectItem::Star { offset });
        }
        let expr = self.expr()?;
        let alias = if self.eat_kw("AS") {
            Some(self.ident("alias after AS")?.0)
        } else {
            // Bare alias: `SELECT hour h FROM …`.
            match self.peek() {
                Some(Token { tok: Tok::Ident(s), .. })
                    if !CLAUSE_KWS.iter().any(|k| s.eq_ignore_ascii_case(k)) =>
                {
                    Some(self.ident("alias")?.0)
                }
                _ => None,
            }
        };
        Ok(SelectItem::Expr { expr, alias })
    }

    fn table_ref(&mut self) -> Result<TableRef, SqlError> {
        let (name, offset) = self.ident("table name")?;
        let alias = if self.eat_kw("AS") {
            Some(self.ident("alias after AS")?.0)
        } else {
            match self.peek() {
                Some(Token { tok: Tok::Ident(s), .. })
                    if !CLAUSE_KWS.iter().any(|k| s.eq_ignore_ascii_case(k)) =>
                {
                    Some(self.ident("alias")?.0)
                }
                _ => None,
            }
        };
        Ok(TableRef { name, alias, offset })
    }

    // Precedence climbing: OR < AND < NOT < comparison/BETWEEN < +- < */ < unary.
    fn expr(&mut self) -> Result<Expr, SqlError> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr, SqlError> {
        let mut lhs = self.and_expr()?;
        while self.peek().is_some_and(|t| t.is_kw("OR")) {
            let offset = self.here();
            self.pos += 1;
            let rhs = self.and_expr()?;
            lhs = Expr::Binary { op: BinOp::Or, lhs: Box::new(lhs), rhs: Box::new(rhs), offset };
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, SqlError> {
        let mut lhs = self.not_expr()?;
        while self.peek().is_some_and(|t| t.is_kw("AND")) {
            let offset = self.here();
            self.pos += 1;
            let rhs = self.not_expr()?;
            lhs = Expr::Binary { op: BinOp::And, lhs: Box::new(lhs), rhs: Box::new(rhs), offset };
        }
        Ok(lhs)
    }

    fn not_expr(&mut self) -> Result<Expr, SqlError> {
        if self.peek().is_some_and(|t| t.is_kw("NOT")) {
            let offset = self.here();
            self.pos += 1;
            let expr = self.not_expr()?;
            return Ok(Expr::Not { expr: Box::new(expr), offset });
        }
        self.comparison()
    }

    fn comparison(&mut self) -> Result<Expr, SqlError> {
        let lhs = self.additive()?;
        if self.peek().is_some_and(|t| t.is_kw("BETWEEN")) {
            let offset = self.here();
            self.pos += 1;
            let lo = self.additive()?;
            self.expect_kw("AND")?;
            let hi = self.additive()?;
            return Ok(Expr::Between {
                expr: Box::new(lhs),
                lo: Box::new(lo),
                hi: Box::new(hi),
                offset,
            });
        }
        let op = match self.peek().map(|t| &t.tok) {
            Some(Tok::Sym(Sym::Eq)) => BinOp::Eq,
            Some(Tok::Sym(Sym::NotEq)) => BinOp::NotEq,
            Some(Tok::Sym(Sym::Lt)) => BinOp::Lt,
            Some(Tok::Sym(Sym::Le)) => BinOp::Le,
            Some(Tok::Sym(Sym::Gt)) => BinOp::Gt,
            Some(Tok::Sym(Sym::Ge)) => BinOp::Ge,
            _ => return Ok(lhs),
        };
        let offset = self.here();
        self.pos += 1;
        let rhs = self.additive()?;
        Ok(Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs), offset })
    }

    fn additive(&mut self) -> Result<Expr, SqlError> {
        let mut lhs = self.multiplicative()?;
        loop {
            let op = match self.peek().map(|t| &t.tok) {
                Some(Tok::Sym(Sym::Plus)) => BinOp::Add,
                Some(Tok::Sym(Sym::Minus)) => BinOp::Sub,
                _ => break,
            };
            let offset = self.here();
            self.pos += 1;
            let rhs = self.multiplicative()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs), offset };
        }
        Ok(lhs)
    }

    fn multiplicative(&mut self) -> Result<Expr, SqlError> {
        let mut lhs = self.unary()?;
        loop {
            let op = match self.peek().map(|t| &t.tok) {
                Some(Tok::Sym(Sym::Star)) => BinOp::Mul,
                Some(Tok::Sym(Sym::Slash)) => BinOp::Div,
                _ => break,
            };
            let offset = self.here();
            self.pos += 1;
            let rhs = self.unary()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs), offset };
        }
        Ok(lhs)
    }

    fn unary(&mut self) -> Result<Expr, SqlError> {
        if self.eat_sym(Sym::Plus) {
            return self.unary();
        }
        if let Some(Token { tok: Tok::Sym(Sym::Minus), offset }) = self.peek() {
            let offset = *offset;
            self.pos += 1;
            let expr = self.unary()?;
            return Ok(Expr::Neg { expr: Box::new(expr), offset });
        }
        self.primary()
    }

    fn primary(&mut self) -> Result<Expr, SqlError> {
        let Some(t) = self.peek().cloned() else {
            return Err(self.err_here("an expression"));
        };
        match &t.tok {
            Tok::Number(n) => {
                self.pos += 1;
                Ok(Expr::Number { value: *n, offset: t.offset })
            }
            Tok::Str(s) => {
                self.pos += 1;
                Ok(Expr::Str { value: s.clone(), offset: t.offset })
            }
            Tok::Sym(Sym::LParen) => {
                self.pos += 1;
                let inner = self.expr()?;
                self.expect_sym(Sym::RParen)?;
                Ok(inner)
            }
            Tok::Ident(name) => {
                if let Some(func) = AggFunc::from_ident(name) {
                    // Aggregate call only when followed by `(`; otherwise
                    // treat `count`/`min` etc. as a plain identifier.
                    if self.tokens.get(self.pos + 1).map(|t| &t.tok)
                        == Some(&Tok::Sym(Sym::LParen))
                    {
                        self.pos += 2;
                        if func == AggFunc::Count && self.eat_sym(Sym::Star) {
                            self.expect_sym(Sym::RParen)?;
                            return Ok(Expr::Agg { func, arg: None, offset: t.offset });
                        }
                        let arg = self.expr()?;
                        self.expect_sym(Sym::RParen)?;
                        return Ok(Expr::Agg {
                            func,
                            arg: Some(Box::new(arg)),
                            offset: t.offset,
                        });
                    }
                }
                if CLAUSE_KWS.iter().any(|k| name.eq_ignore_ascii_case(k)) {
                    return Err(self.err_here("an expression"));
                }
                let (first, offset) = self.ident("a column name")?;
                if self.eat_sym(Sym::Dot) {
                    let (col, _) = self.ident("a column name after `.`")?;
                    Ok(Expr::Column { table: Some(first), name: col, offset })
                } else {
                    Ok(Expr::Column { table: None, name: first, offset })
                }
            }
            _ => Err(self.err_here("an expression")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_clause_set() {
        let s = parse(
            "EXPLAIN SELECT hour, COUNT(*) AS n FROM trips t \
             JOIN weather w ON t.day = w.day \
             WHERE tip_amount > 1 AND day BETWEEN 10 AND 20 \
             GROUP BY hour HAVING COUNT(*) > 5 \
             ORDER BY n DESC, hour LIMIT 7;",
        )
        .unwrap();
        assert!(s.explain);
        let q = s.query;
        assert_eq!(q.items.len(), 2);
        assert_eq!(q.from.alias.as_deref(), Some("t"));
        assert!(q.join.is_some());
        assert!(q.where_clause.is_some());
        assert_eq!(q.group_by.len(), 1);
        assert!(q.having.is_some());
        assert_eq!(q.order_by.len(), 2);
        assert!(q.order_by[0].desc);
        assert!(!q.order_by[1].desc);
        assert_eq!(q.limit, Some(7));
    }

    #[test]
    fn precedence_and_asts() {
        let s = parse("SELECT a + b * 2 FROM trips WHERE NOT a = 1 OR b = 2 AND c = 3").unwrap();
        let SelectItem::Expr { expr, .. } = &s.query.items[0] else { panic!() };
        // a + (b * 2)
        let Expr::Binary { op: BinOp::Add, rhs, .. } = expr else { panic!("{expr:?}") };
        assert!(matches!(&**rhs, Expr::Binary { op: BinOp::Mul, .. }));
        // (NOT (a=1)) OR ((b=2) AND (c=3))
        let w = s.query.where_clause.unwrap();
        let Expr::Binary { op: BinOp::Or, lhs, rhs, .. } = w else { panic!("{w:?}") };
        assert!(matches!(&*lhs, Expr::Not { .. }));
        assert!(matches!(&*rhs, Expr::Binary { op: BinOp::And, .. }));
    }

    #[test]
    fn count_star_and_qualified_columns() {
        let s = parse("SELECT COUNT(*), SUM(t.tip_amount) FROM trips t").unwrap();
        let SelectItem::Expr { expr, .. } = &s.query.items[0] else { panic!() };
        assert!(matches!(expr, Expr::Agg { func: AggFunc::Count, arg: None, .. }));
        let SelectItem::Expr { expr, .. } = &s.query.items[1] else { panic!() };
        let Expr::Agg { func: AggFunc::Sum, arg: Some(a), .. } = expr else { panic!() };
        assert!(
            matches!(&**a, Expr::Column { table: Some(t), name, .. } if t == "t" && name == "tip_amount")
        );
    }

    #[test]
    fn errors_point_into_the_text() {
        let text = "SELECT FROM trips";
        let e = parse(text).unwrap_err();
        assert_eq!(e.offset, 7, "{e}");
        let text = "SELECT a FROM";
        let e = parse(text).unwrap_err();
        assert_eq!(e.offset, text.len());
        let e = parse("SELECT a FROM t LIMIT x").unwrap_err();
        assert_eq!(e.offset, 22);
        let e = parse("SELECT a FROM t WHERE a BETWEEN 1 2").unwrap_err();
        assert!(e.message.contains("AND"), "{e}");
        // Trailing garbage after a complete statement.
        let e = parse("SELECT a FROM t; SELECT b FROM t").unwrap_err();
        assert_eq!(e.offset, 17);
    }
}
