//! Name resolution and the typed logical plan.
//!
//! The catalog is the two schemas the engine ships — the NYC taxi trip
//! table (`data::schema::TripRecord`, plus the derived `day`/`month`/
//! `hour`/`credit` columns every Table I query aggregates on) and the
//! daily weather table (`data::weather`, plus the derived precipitation
//! `bucket`). Analysis turns the raw AST into [`Scalar`] expressions
//! over [`Column`]s, splits the WHERE clause into conjuncts, and
//! classifies the query as a plain projection or a grouped aggregation
//! with typed [`Aggregate`] slots — everything the rewriter and the
//! cost-based physical planner downstream operate on.

use crate::sql::lex::SqlError;
use crate::sql::parse::{AggFunc, BinOp, Expr, SelectItem, SelectQuery, TableRef};
use std::collections::BTreeSet;
use std::fmt::Write as _;

/// A registered table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Table {
    Trips,
    Weather,
}

impl Table {
    pub fn name(self) -> &'static str {
        match self {
            Table::Trips => "trips",
            Table::Weather => "weather",
        }
    }

    pub fn bucket(self) -> &'static str {
        crate::data::INPUT_BUCKET
    }

    /// Object-store prefix the table's CSV objects live under.
    pub fn prefix(self) -> &'static str {
        match self {
            Table::Trips => "trips/",
            Table::Weather => "weather/",
        }
    }

    pub fn resolve(name: &str) -> Option<Table> {
        if name.eq_ignore_ascii_case("trips") {
            Some(Table::Trips)
        } else if name.eq_ignore_ascii_case("weather") {
            Some(Table::Weather)
        } else {
            None
        }
    }

    /// Catalog columns in declaration order (`SELECT *` order).
    pub fn columns(self) -> &'static [Column] {
        use Column::*;
        match self {
            Table::Trips => &[
                TaxiType,
                Day,
                Month,
                Hour,
                PassengerCount,
                TripDistance,
                PickupLon,
                PickupLat,
                DropoffLon,
                DropoffLat,
                PaymentType,
                Credit,
                FareAmount,
                TipAmount,
                TotalAmount,
            ],
            Table::Weather => &[WeatherDay, Precip, Bucket],
        }
    }

    pub fn lookup(self, name: &str) -> Option<Column> {
        self.columns().iter().copied().find(|c| c.name().eq_ignore_ascii_case(name))
    }
}

/// A resolved column. Trip columns cover the 13 physical CSV fields
/// plus the derived time (`day`/`month`/`hour`, from the dropoff
/// datetime — the paper aggregates on dropoff) and payment (`credit`)
/// columns; weather columns cover the two physical fields plus the
/// derived precipitation `bucket`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Column {
    // trips
    TaxiType,
    Day,
    Month,
    Hour,
    PassengerCount,
    TripDistance,
    PickupLon,
    PickupLat,
    DropoffLon,
    DropoffLat,
    PaymentType,
    Credit,
    FareAmount,
    TipAmount,
    TotalAmount,
    // weather
    WeatherDay,
    Precip,
    Bucket,
}

impl Column {
    pub fn table(self) -> Table {
        match self {
            Column::WeatherDay | Column::Precip | Column::Bucket => Table::Weather,
            _ => Table::Trips,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Column::TaxiType => "taxi_type",
            Column::Day => "day",
            Column::Month => "month",
            Column::Hour => "hour",
            Column::PassengerCount => "passenger_count",
            Column::TripDistance => "trip_distance",
            Column::PickupLon => "pickup_lon",
            Column::PickupLat => "pickup_lat",
            Column::DropoffLon => "dropoff_lon",
            Column::DropoffLat => "dropoff_lat",
            Column::PaymentType => "payment_type",
            Column::Credit => "credit",
            Column::FareAmount => "fare_amount",
            Column::TipAmount => "tip_amount",
            Column::TotalAmount => "total_amount",
            Column::WeatherDay => "day",
            Column::Precip => "precip",
            Column::Bucket => "bucket",
        }
    }

    /// Rendered name — weather columns are prefixed so `day` (trips)
    /// and `weather.day` stay distinct in EXPLAIN output.
    pub fn display(self) -> String {
        match self.table() {
            Table::Trips => self.name().to_string(),
            Table::Weather => format!("weather.{}", self.name()),
        }
    }

    /// Integer-valued columns (affects output rendering and key typing).
    pub fn is_int(self) -> bool {
        !matches!(
            self,
            Column::TripDistance
                | Column::PickupLon
                | Column::PickupLat
                | Column::DropoffLon
                | Column::DropoffLat
                | Column::FareAmount
                | Column::TipAmount
                | Column::TotalAmount
                | Column::Precip
        )
    }

    /// Estimated number of distinct values, where the schema bounds it —
    /// what the planner sizes aggregation partition counts from.
    pub fn ndv(self) -> Option<u64> {
        match self {
            Column::TaxiType => Some(2),
            Column::Hour => Some(24),
            Column::Month => Some(90), // Jan 2009 .. Jun 2016
            Column::Day | Column::WeatherDay => Some(crate::data::weather::NUM_DAYS as u64),
            Column::PaymentType => Some(6),
            Column::Credit => Some(2),
            Column::Bucket => Some(crate::data::weather::PRECIP_BUCKETS as u64),
            Column::PassengerCount => Some(8),
            _ => None,
        }
    }
}

/// A typed, resolved expression over catalog columns. Numeric
/// evaluation is over `f64` (booleans as 0/1), matching the dynamic
/// `Value` runtime.
#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    Col(Column),
    LitI(i64),
    LitF(f64),
    Neg(Box<Scalar>),
    Not(Box<Scalar>),
    Bin(BinOp, Box<Scalar>, Box<Scalar>),
    Between(Box<Scalar>, Box<Scalar>, Box<Scalar>),
}

impl Scalar {
    pub fn lit(v: f64) -> Scalar {
        if v.fract() == 0.0 && v.abs() < 9e15 {
            Scalar::LitI(v as i64)
        } else {
            Scalar::LitF(v)
        }
    }

    /// Evaluate against a row accessor (booleans are 1.0 / 0.0).
    pub fn eval(&self, col: &impl Fn(Column) -> f64) -> f64 {
        match self {
            Scalar::Col(c) => col(*c),
            Scalar::LitI(v) => *v as f64,
            Scalar::LitF(v) => *v,
            Scalar::Neg(e) => -e.eval(col),
            Scalar::Not(e) => f64::from(e.eval(col) == 0.0),
            Scalar::Between(e, lo, hi) => {
                let v = e.eval(col);
                f64::from(v >= lo.eval(col) && v <= hi.eval(col))
            }
            Scalar::Bin(op, l, r) => {
                let a = l.eval(col);
                match op {
                    BinOp::And => return f64::from(a != 0.0 && r.eval(col) != 0.0),
                    BinOp::Or => return f64::from(a != 0.0 || r.eval(col) != 0.0),
                    _ => {}
                }
                let b = r.eval(col);
                match op {
                    BinOp::Add => a + b,
                    BinOp::Sub => a - b,
                    BinOp::Mul => a * b,
                    BinOp::Div => a / b,
                    BinOp::Eq => f64::from(a == b),
                    BinOp::NotEq => f64::from(a != b),
                    BinOp::Lt => f64::from(a < b),
                    BinOp::Le => f64::from(a <= b),
                    BinOp::Gt => f64::from(a > b),
                    BinOp::Ge => f64::from(a >= b),
                    BinOp::And | BinOp::Or => unreachable!(),
                }
            }
        }
    }

    /// Truth test for predicates.
    pub fn test(&self, col: &impl Fn(Column) -> f64) -> bool {
        self.eval(col) != 0.0
    }

    pub fn columns_into(&self, out: &mut BTreeSet<Column>) {
        match self {
            Scalar::Col(c) => {
                out.insert(*c);
            }
            Scalar::LitI(_) | Scalar::LitF(_) => {}
            Scalar::Neg(e) | Scalar::Not(e) => e.columns_into(out),
            Scalar::Bin(_, l, r) => {
                l.columns_into(out);
                r.columns_into(out);
            }
            Scalar::Between(e, lo, hi) => {
                e.columns_into(out);
                lo.columns_into(out);
                hi.columns_into(out);
            }
        }
    }

    pub fn columns(&self) -> BTreeSet<Column> {
        let mut out = BTreeSet::new();
        self.columns_into(&mut out);
        out
    }

    /// Which tables this expression touches.
    pub fn tables(&self) -> BTreeSet<&'static str> {
        self.columns().iter().map(|c| c.table().name()).collect()
    }

    pub fn is_const(&self) -> bool {
        self.columns().is_empty()
    }

    /// Integer-valued under evaluation (drives output/key typing).
    pub fn is_int(&self) -> bool {
        match self {
            Scalar::Col(c) => c.is_int(),
            Scalar::LitI(_) => true,
            Scalar::LitF(_) => false,
            Scalar::Neg(e) => e.is_int(),
            Scalar::Not(_) | Scalar::Between(..) => true,
            Scalar::Bin(op, l, r) => match op {
                BinOp::Add | BinOp::Sub | BinOp::Mul => l.is_int() && r.is_int(),
                BinOp::Div => false,
                _ => true,
            },
        }
    }

    /// Estimated distinct values this expression can take (for
    /// partition-count picking). Unknown → `u64::MAX`.
    pub fn ndv(&self) -> u64 {
        self.ndv_refined(&|_| None)
    }

    /// [`Scalar::ndv`] with per-column refinements: the physical planner
    /// passes stats-derived bounds (the day/month spans a scan's splits
    /// actually cover), which tighten the schema-wide domain. A
    /// refinement never widens — the schema estimate stays the ceiling.
    pub fn ndv_refined(&self, refine: &dyn Fn(Column) -> Option<u64>) -> u64 {
        match self {
            Scalar::Col(c) => {
                let schema = c.ndv().unwrap_or(u64::MAX);
                refine(*c).map_or(schema, |n| n.min(schema))
            }
            Scalar::LitI(_) | Scalar::LitF(_) => 1,
            Scalar::Neg(e) => e.ndv_refined(refine),
            Scalar::Not(_) | Scalar::Between(..) => 2,
            Scalar::Bin(op, l, r) => {
                if op.is_comparison() || matches!(op, BinOp::And | BinOp::Or) {
                    2
                } else {
                    l.ndv_refined(refine).saturating_mul(r.ndv_refined(refine))
                }
            }
        }
    }

    pub fn render(&self) -> String {
        match self {
            Scalar::Col(c) => c.display(),
            Scalar::LitI(v) => format!("{v}"),
            Scalar::LitF(v) => format!("{v}"),
            Scalar::Neg(e) => format!("(-{})", e.render()),
            Scalar::Not(e) => format!("(NOT {})", e.render()),
            Scalar::Bin(op, l, r) => format!("({} {} {})", l.render(), op.text(), r.render()),
            Scalar::Between(e, lo, hi) => {
                format!("({} BETWEEN {} AND {})", e.render(), lo.render(), hi.render())
            }
        }
    }
}

/// One aggregate slot: `COUNT(*)` carries no argument.
#[derive(Debug, Clone, PartialEq)]
pub struct Aggregate {
    pub func: AggFunc,
    pub arg: Option<Scalar>,
}

impl Aggregate {
    pub fn render(&self) -> String {
        match &self.arg {
            None => format!("{}(*)", self.func.name()),
            Some(a) => format!("{}({})", self.func.name(), a.render()),
        }
    }

    pub fn is_int(&self) -> bool {
        match self.func {
            AggFunc::Count => true,
            AggFunc::Avg => false,
            AggFunc::Sum | AggFunc::Min | AggFunc::Max => {
                self.arg.as_ref().is_some_and(Scalar::is_int)
            }
        }
    }
}

/// An output expression over the aggregation's computed keys and
/// aggregate slots — what SELECT items and HAVING become in a grouped
/// query (`SUM(credit) / COUNT(*)` is `Bin(Div, Agg(0), Agg(1))`).
#[derive(Debug, Clone, PartialEq)]
pub enum OutExpr {
    Key(usize),
    Agg(usize),
    LitI(i64),
    LitF(f64),
    Neg(Box<OutExpr>),
    Not(Box<OutExpr>),
    Bin(BinOp, Box<OutExpr>, Box<OutExpr>),
}

impl OutExpr {
    pub fn eval(&self, keys: &[f64], aggs: &[f64]) -> f64 {
        match self {
            OutExpr::Key(i) => keys[*i],
            OutExpr::Agg(i) => aggs[*i],
            OutExpr::LitI(v) => *v as f64,
            OutExpr::LitF(v) => *v,
            OutExpr::Neg(e) => -e.eval(keys, aggs),
            OutExpr::Not(e) => f64::from(e.eval(keys, aggs) == 0.0),
            OutExpr::Bin(op, l, r) => {
                let a = l.eval(keys, aggs);
                let b = r.eval(keys, aggs);
                match op {
                    BinOp::Add => a + b,
                    BinOp::Sub => a - b,
                    BinOp::Mul => a * b,
                    BinOp::Div => a / b,
                    BinOp::Eq => f64::from(a == b),
                    BinOp::NotEq => f64::from(a != b),
                    BinOp::Lt => f64::from(a < b),
                    BinOp::Le => f64::from(a <= b),
                    BinOp::Gt => f64::from(a > b),
                    BinOp::Ge => f64::from(a >= b),
                    BinOp::And => f64::from(a != 0.0 && b != 0.0),
                    BinOp::Or => f64::from(a != 0.0 || b != 0.0),
                }
            }
        }
    }

    fn render(&self, keys: &[Scalar], aggs: &[Aggregate]) -> String {
        match self {
            OutExpr::Key(i) => keys[*i].render(),
            OutExpr::Agg(i) => aggs[*i].render(),
            OutExpr::LitI(v) => format!("{v}"),
            OutExpr::LitF(v) => format!("{v}"),
            OutExpr::Neg(e) => format!("(-{})", e.render(keys, aggs)),
            OutExpr::Not(e) => format!("(NOT {})", e.render(keys, aggs)),
            OutExpr::Bin(op, l, r) => {
                format!("({} {} {})", l.render(keys, aggs), op.text(), r.render(keys, aggs))
            }
        }
    }

    fn is_int(&self, keys: &[Scalar], aggs: &[Aggregate]) -> bool {
        match self {
            OutExpr::Key(i) => keys[*i].is_int(),
            OutExpr::Agg(i) => aggs[*i].is_int(),
            OutExpr::LitI(_) => true,
            OutExpr::LitF(_) => false,
            OutExpr::Neg(e) => e.is_int(keys, aggs),
            OutExpr::Not(_) => true,
            OutExpr::Bin(op, l, r) => match op {
                BinOp::Add | BinOp::Sub | BinOp::Mul => {
                    l.is_int(keys, aggs) && r.is_int(keys, aggs)
                }
                BinOp::Div => false,
                _ => true,
            },
        }
    }
}

/// One predicate pushed into a scan, in WHERE-clause source order.
#[derive(Debug, Clone, PartialEq)]
pub enum PushedPred {
    /// A typed inclusive day range extracted from a `day`/`month`
    /// conjunct — lowers to [`crate::plan::DynOp::DayRange`], which the
    /// engine's stats-based pruning can skip whole splits with.
    DayRange { lo: i32, hi: i32 },
    /// An opaque conjunct, evaluated against the raw line during the
    /// scan.
    Generic(Scalar),
}

impl PushedPred {
    pub fn render(&self) -> String {
        match self {
            PushedPred::DayRange { lo, hi } => format!("day_range[{lo}..={hi}]"),
            PushedPred::Generic(s) => s.render(),
        }
    }
}

/// One table scan with whatever the rewriter managed to push into it.
#[derive(Debug, Clone, PartialEq)]
pub struct TableScan {
    pub table: Table,
    /// Conjuncts pushed below the join into this scan, in source order
    /// (day-range extraction rewrites entries in place, so an opaque
    /// conjunct can legitimately precede a `DayRange` — pruning still
    /// fires because `leading_day_range` commutes past pure filters).
    pub pushed: Vec<PushedPred>,
    /// Columns the scan materializes; `None` = all (projection pushdown
    /// not applied yet).
    pub projected: Option<Vec<Column>>,
}

impl TableScan {
    fn new(table: Table) -> TableScan {
        TableScan { table, pushed: Vec::new(), projected: None }
    }

    pub fn columns(&self) -> Vec<Column> {
        self.projected.clone().unwrap_or_else(|| self.table.columns().to_vec())
    }

    /// Extracted day ranges, in pushed order.
    pub fn day_ranges(&self) -> Vec<(i32, i32)> {
        self.pushed
            .iter()
            .filter_map(|p| match p {
                PushedPred::DayRange { lo, hi } => Some((*lo, *hi)),
                PushedPred::Generic(_) => None,
            })
            .collect()
    }

    /// Pushed opaque conjuncts, in pushed order.
    pub fn generic_preds(&self) -> Vec<&Scalar> {
        self.pushed
            .iter()
            .filter_map(|p| match p {
                PushedPred::Generic(s) => Some(s),
                PushedPred::DayRange { .. } => None,
            })
            .collect()
    }

    fn render(&self) -> String {
        let mut s = format!("Scan {}", self.table.name());
        match &self.projected {
            None => s.push_str(" columns=[*]"),
            Some(cols) => {
                let names: Vec<&str> = cols.iter().map(|c| c.name()).collect();
                let _ = write!(s, " columns=[{}]", names.join(", "));
            }
        }
        if !self.pushed.is_empty() {
            let preds: Vec<String> = self.pushed.iter().map(PushedPred::render).collect();
            let _ = write!(s, " pushed=[{}]", preds.join(" AND "));
        }
        s
    }
}

/// The (single, equi-) join of the logical plan.
#[derive(Debug, Clone, PartialEq)]
pub struct JoinInfo {
    pub dim: TableScan,
    /// Key expression over the FROM-side table.
    pub fact_key: Scalar,
    /// Key expression over the JOIN-side table.
    pub dim_key: Scalar,
}

/// What the query computes per surviving row.
#[derive(Debug, Clone, PartialEq)]
pub enum Mode {
    /// Plain `SELECT expr, …` — one output row per input row.
    Project { exprs: Vec<Scalar> },
    /// `GROUP BY` / aggregate query: shuffle on `keys`, fold `aggs`,
    /// then evaluate `select` per group.
    Aggregate { keys: Vec<Scalar>, aggs: Vec<Aggregate>, select: Vec<OutExpr> },
}

/// The analyzed (and, after `rewrite`, optimized) logical plan.
#[derive(Debug, Clone, PartialEq)]
pub struct LogicalPlan {
    pub fact: TableScan,
    pub join: Option<JoinInfo>,
    /// Conjuncts evaluated above the join (or above the scan when there
    /// is none). Pushdown drains single-table conjuncts out of here.
    pub filter: Vec<Scalar>,
    pub mode: Mode,
    pub having: Option<OutExpr>,
    /// Output column names (aliases or rendered expressions).
    pub columns: Vec<String>,
    /// Whether each output column is integer-valued.
    pub int_outputs: Vec<bool>,
    /// `(select index, descending)` — applied at the driver.
    pub order_by: Vec<(usize, bool)>,
    pub limit: Option<usize>,
}

impl LogicalPlan {
    /// Render the plan tree (EXPLAIN's logical / optimized sections).
    pub fn render(&self) -> String {
        let mut lines: Vec<String> = Vec::new();
        if let Some(n) = self.limit {
            lines.push(format!("Limit {n}"));
        }
        if !self.order_by.is_empty() {
            let keys: Vec<String> = self
                .order_by
                .iter()
                .map(|(i, desc)| {
                    format!("{}{}", self.columns[*i], if *desc { " DESC" } else { "" })
                })
                .collect();
            lines.push(format!("Sort [{}]", keys.join(", ")));
        }
        match &self.mode {
            Mode::Project { exprs } => {
                let items: Vec<String> = exprs.iter().map(Scalar::render).collect();
                lines.push(format!("Project [{}]", items.join(", ")));
            }
            Mode::Aggregate { keys, aggs, select } => {
                let ks: Vec<String> = keys.iter().map(Scalar::render).collect();
                let ags: Vec<String> = aggs.iter().map(Aggregate::render).collect();
                let sel: Vec<String> = select.iter().map(|e| e.render(keys, aggs)).collect();
                let mut line = format!(
                    "Aggregate keys=[{}] aggs=[{}] select=[{}]",
                    ks.join(", "),
                    ags.join(", "),
                    sel.join(", ")
                );
                if let Some(h) = &self.having {
                    let _ = write!(line, " having={}", h.render(keys, aggs));
                }
                lines.push(line);
            }
        }
        if !self.filter.is_empty() {
            let preds: Vec<String> = self.filter.iter().map(Scalar::render).collect();
            lines.push(format!("Filter [{}]", preds.join(" AND ")));
        }
        let mut out = String::new();
        for (depth, line) in lines.iter().enumerate() {
            let _ = writeln!(out, "{}{}", "  ".repeat(depth), line);
        }
        let depth = lines.len();
        match &self.join {
            None => {
                let _ = writeln!(out, "{}{}", "  ".repeat(depth), self.fact.render());
            }
            Some(j) => {
                let _ = writeln!(
                    out,
                    "{}Join on {} = {}",
                    "  ".repeat(depth),
                    j.fact_key.render(),
                    j.dim_key.render()
                );
                let _ = writeln!(out, "{}{}", "  ".repeat(depth + 1), self.fact.render());
                let _ = writeln!(out, "{}{}", "  ".repeat(depth + 1), j.dim.render());
            }
        }
        out
    }

    /// Every column the plan references on `table` (for projection
    /// pushdown and the scan parsers).
    pub fn referenced_columns(&self, table: Table) -> Vec<Column> {
        let mut set = BTreeSet::new();
        for pred in &self.filter {
            pred.columns_into(&mut set);
        }
        for pred in self.fact.generic_preds() {
            pred.columns_into(&mut set);
        }
        if let Some(j) = &self.join {
            j.fact_key.columns_into(&mut set);
            j.dim_key.columns_into(&mut set);
            for pred in j.dim.generic_preds() {
                pred.columns_into(&mut set);
            }
        }
        match &self.mode {
            Mode::Project { exprs } => {
                for e in exprs {
                    e.columns_into(&mut set);
                }
            }
            Mode::Aggregate { keys, aggs, .. } => {
                for k in keys {
                    k.columns_into(&mut set);
                }
                for a in aggs {
                    if let Some(arg) = &a.arg {
                        arg.columns_into(&mut set);
                    }
                }
            }
        }
        set.into_iter().filter(|c| c.table() == table).collect()
    }
}

// ---------------------------------------------------------------------
// Analysis: AST -> LogicalPlan
// ---------------------------------------------------------------------

/// A FROM/JOIN binding: which catalog table an alias refers to.
struct Binding {
    table: Table,
    /// The name columns may be qualified with (alias if given, else the
    /// table name).
    qualifier: String,
}

struct Analyzer {
    bindings: Vec<Binding>,
}

impl Analyzer {
    fn bind(r: &TableRef) -> Result<(Table, Binding), SqlError> {
        let table = Table::resolve(&r.name).ok_or_else(|| {
            SqlError::new(
                format!("unknown table `{}` (known: trips, weather)", r.name),
                r.offset,
            )
        })?;
        let qualifier = r.alias.clone().unwrap_or_else(|| r.name.clone());
        Ok((table, Binding { table, qualifier }))
    }

    fn resolve_column(
        &self,
        table: &Option<String>,
        name: &str,
        offset: usize,
    ) -> Result<Column, SqlError> {
        match table {
            Some(q) => {
                let b = self
                    .bindings
                    .iter()
                    .find(|b| b.qualifier.eq_ignore_ascii_case(q))
                    .ok_or_else(|| {
                        SqlError::new(format!("unknown table or alias `{q}`"), offset)
                    })?;
                b.table.lookup(name).ok_or_else(|| {
                    SqlError::new(
                        format!("no column `{name}` in table `{}`", b.table.name()),
                        offset,
                    )
                })
            }
            None => {
                let hits: Vec<Column> =
                    self.bindings.iter().filter_map(|b| b.table.lookup(name)).collect();
                match hits.len() {
                    0 => Err(SqlError::new(format!("unknown column `{name}`"), offset)),
                    1 => Ok(hits[0]),
                    _ => Err(SqlError::new(
                        format!("ambiguous column `{name}` — qualify it with a table alias"),
                        offset,
                    )),
                }
            }
        }
    }

    /// AST expression -> Scalar. Aggregates are rejected (`where_ok`
    /// contexts: WHERE / GROUP BY / ON / plain select items).
    fn scalar(&self, e: &Expr) -> Result<Scalar, SqlError> {
        match e {
            Expr::Column { table, name, offset } => {
                Ok(Scalar::Col(self.resolve_column(table, name, *offset)?))
            }
            Expr::Number { value, .. } => Ok(Scalar::lit(*value)),
            Expr::Str { offset, .. } => Err(SqlError::new(
                "string literals are not supported in expressions (no string columns)",
                *offset,
            )),
            Expr::Neg { expr, .. } => Ok(Scalar::Neg(Box::new(self.scalar(expr)?))),
            Expr::Not { expr, .. } => Ok(Scalar::Not(Box::new(self.scalar(expr)?))),
            Expr::Binary { op, lhs, rhs, .. } => Ok(Scalar::Bin(
                *op,
                Box::new(self.scalar(lhs)?),
                Box::new(self.scalar(rhs)?),
            )),
            Expr::Between { expr, lo, hi, .. } => Ok(Scalar::Between(
                Box::new(self.scalar(expr)?),
                Box::new(self.scalar(lo)?),
                Box::new(self.scalar(hi)?),
            )),
            Expr::Agg { offset, .. } => {
                Err(SqlError::new("aggregate function is not allowed here", *offset))
            }
        }
    }
}

/// Split an AND-tree into conjuncts (WHERE lowering).
fn split_conjuncts(e: &Expr, out: &mut Vec<Expr>) {
    if let Expr::Binary { op: BinOp::And, lhs, rhs, .. } = e {
        split_conjuncts(lhs, out);
        split_conjuncts(rhs, out);
    } else {
        out.push(e.clone());
    }
}

/// Collects aggregate slots while converting select/having expressions.
struct OutBuilder<'a> {
    az: &'a Analyzer,
    keys: Vec<Scalar>,
    key_renders: Vec<String>,
    aggs: Vec<Aggregate>,
    agg_renders: Vec<String>,
}

impl OutBuilder<'_> {
    fn convert(&mut self, e: &Expr) -> Result<OutExpr, SqlError> {
        // A whole non-aggregate subtree that matches a GROUP BY key is a
        // key reference — the only way plain columns reach the output.
        if !e.has_agg() {
            if let Ok(s) = self.az.scalar(e) {
                let r = s.render();
                if let Some(i) = self.key_renders.iter().position(|k| *k == r) {
                    return Ok(OutExpr::Key(i));
                }
                if s.is_const() {
                    return Ok(match s {
                        Scalar::LitI(v) => OutExpr::LitI(v),
                        Scalar::LitF(v) => OutExpr::LitF(v),
                        other => OutExpr::LitF(other.eval(&|_| 0.0)),
                    });
                }
            }
        }
        match e {
            Expr::Agg { func, arg, offset } => {
                let arg = match arg {
                    None => None,
                    Some(a) => {
                        if a.has_agg() {
                            return Err(SqlError::new("nested aggregate", *offset));
                        }
                        Some(self.az.scalar(a)?)
                    }
                };
                let agg = Aggregate { func: *func, arg };
                let r = agg.render();
                let i = match self.agg_renders.iter().position(|a| *a == r) {
                    Some(i) => i,
                    None => {
                        self.aggs.push(agg);
                        self.agg_renders.push(r);
                        self.aggs.len() - 1
                    }
                };
                Ok(OutExpr::Agg(i))
            }
            Expr::Number { value, .. } => Ok(match Scalar::lit(*value) {
                Scalar::LitI(v) => OutExpr::LitI(v),
                s => OutExpr::LitF(s.eval(&|_| 0.0)),
            }),
            Expr::Neg { expr, .. } => Ok(OutExpr::Neg(Box::new(self.convert(expr)?))),
            Expr::Not { expr, .. } => Ok(OutExpr::Not(Box::new(self.convert(expr)?))),
            Expr::Binary { op, lhs, rhs, .. } => Ok(OutExpr::Bin(
                *op,
                Box::new(self.convert(lhs)?),
                Box::new(self.convert(rhs)?),
            )),
            Expr::Between { expr, lo, hi, offset } => {
                // Desugar: e BETWEEN a AND b  ==  a <= e AND e <= b.
                let e2 = Expr::Binary {
                    op: BinOp::And,
                    lhs: Box::new(Expr::Binary {
                        op: BinOp::Le,
                        lhs: lo.clone(),
                        rhs: expr.clone(),
                        offset: *offset,
                    }),
                    rhs: Box::new(Expr::Binary {
                        op: BinOp::Le,
                        lhs: expr.clone(),
                        rhs: hi.clone(),
                        offset: *offset,
                    }),
                    offset: *offset,
                };
                self.convert(&e2)
            }
            Expr::Column { name, offset, .. } => Err(SqlError::new(
                format!("column `{name}` must appear in GROUP BY or inside an aggregate"),
                *offset,
            )),
            Expr::Str { offset, .. } => Err(SqlError::new(
                "string literals are not supported in expressions (no string columns)",
                *offset,
            )),
        }
    }
}

/// Default rendered name of a select item (when it has no alias).
fn item_name(e: &Expr, az: &Analyzer) -> String {
    match e {
        Expr::Column { name, .. } => name.clone(),
        Expr::Agg { func, arg: None, .. } => format!("{}(*)", func.name()),
        Expr::Agg { func, arg: Some(a), .. } => match az.scalar(a) {
            Ok(s) => format!("{}({})", func.name(), s.render()),
            Err(_) => format!("{}(expr)", func.name()),
        },
        other => match az.scalar(other) {
            Ok(s) => s.render(),
            Err(_) => "expr".to_string(),
        },
    }
}

/// Analyze a parsed query into the (unoptimized) logical plan: all
/// WHERE conjuncts sit in `filter`, scans project every column, no day
/// ranges are extracted — the rewriter's job.
pub fn analyze(q: &SelectQuery) -> Result<LogicalPlan, SqlError> {
    let (fact_table, fact_binding) = Analyzer::bind(&q.from)?;
    let mut bindings = vec![fact_binding];
    let mut dim_table = None;
    if let Some(j) = &q.join {
        let (t, b) = Analyzer::bind(&j.table)?;
        if t == fact_table {
            return Err(SqlError::new(
                format!("self-join of `{}` is not supported", t.name()),
                j.table.offset,
            ));
        }
        bindings.push(b);
        dim_table = Some(t);
    }
    let az = Analyzer { bindings };

    // Join keys: an equality with exactly one side per table.
    let join = match (&q.join, dim_table) {
        (Some(j), Some(dim)) => {
            let Expr::Binary { op: BinOp::Eq, lhs, rhs, offset } = &j.on else {
                return Err(SqlError::new(
                    "JOIN … ON requires an equality condition",
                    j.on.offset(),
                ));
            };
            let l = az.scalar(lhs)?;
            let r = az.scalar(rhs)?;
            let fact_name = fact_table.name();
            let dim_name = dim.name();
            let (fact_key, dim_key) = if l.tables().iter().all(|t| *t == fact_name)
                && r.tables().iter().all(|t| *t == dim_name)
            {
                (l, r)
            } else if l.tables().iter().all(|t| *t == dim_name)
                && r.tables().iter().all(|t| *t == fact_name)
            {
                (r, l)
            } else {
                return Err(SqlError::new(
                    "each side of the join condition must reference exactly one table",
                    *offset,
                ));
            };
            if fact_key.is_const() || dim_key.is_const() {
                return Err(SqlError::new(
                    "each side of the join condition must reference exactly one table",
                    *offset,
                ));
            }
            Some(JoinInfo { dim: TableScan::new(dim), fact_key, dim_key })
        }
        _ => None,
    };

    // WHERE -> conjuncts (all residual until pushdown).
    let mut filter = Vec::new();
    if let Some(w) = &q.where_clause {
        if w.has_agg() {
            return Err(SqlError::new(
                "aggregate function is not allowed in WHERE",
                w.offset(),
            ));
        }
        let mut parts = Vec::new();
        split_conjuncts(w, &mut parts);
        for p in &parts {
            filter.push(az.scalar(p)?);
        }
    }

    let grouped = !q.group_by.is_empty()
        || q.having.is_some()
        || q.items.iter().any(|it| matches!(it, SelectItem::Expr { expr, .. } if expr.has_agg()));

    let mut columns = Vec::new();
    let mut int_outputs = Vec::new();
    let (mode, having, select_renders) = if grouped {
        let mut keys = Vec::new();
        for g in &q.group_by {
            if g.has_agg() {
                return Err(SqlError::new(
                    "aggregate function is not allowed in GROUP BY",
                    g.offset(),
                ));
            }
            keys.push(az.scalar(g)?);
        }
        let key_renders: Vec<String> = keys.iter().map(Scalar::render).collect();
        let mut ob = OutBuilder {
            az: &az,
            keys,
            key_renders,
            aggs: Vec::new(),
            agg_renders: Vec::new(),
        };
        let mut select = Vec::new();
        let mut renders = Vec::new();
        for item in &q.items {
            match item {
                SelectItem::Star { offset } => {
                    return Err(SqlError::new(
                        "SELECT * cannot be combined with GROUP BY or aggregates",
                        *offset,
                    ));
                }
                SelectItem::Expr { expr, alias } => {
                    let out = ob.convert(expr)?;
                    columns.push(alias.clone().unwrap_or_else(|| item_name(expr, &az)));
                    renders.push(out.render(&ob.keys, &ob.aggs));
                    select.push(out);
                }
            }
        }
        let having = match &q.having {
            None => None,
            Some(h) => Some(ob.convert(h)?),
        };
        for s in &select {
            int_outputs.push(s.is_int(&ob.keys, &ob.aggs));
        }
        (
            Mode::Aggregate { keys: ob.keys, aggs: ob.aggs, select },
            having,
            renders,
        )
    } else {
        let mut exprs = Vec::new();
        let mut renders = Vec::new();
        for item in &q.items {
            match item {
                SelectItem::Star { .. } => {
                    for b in &az.bindings {
                        for c in b.table.columns() {
                            columns.push(c.name().to_string());
                            renders.push(Scalar::Col(*c).render());
                            exprs.push(Scalar::Col(*c));
                        }
                    }
                }
                SelectItem::Expr { expr, alias } => {
                    let s = az.scalar(expr)?;
                    columns.push(alias.clone().unwrap_or_else(|| item_name(expr, &az)));
                    renders.push(s.render());
                    exprs.push(s);
                }
            }
        }
        for e in &exprs {
            int_outputs.push(e.is_int());
        }
        (Mode::Project { exprs }, None, renders)
    };

    // ORDER BY: positional (1-based), alias, or a select-matching expr.
    let mut order_by = Vec::new();
    for item in &q.order_by {
        let idx = match &item.expr {
            Expr::Number { value, offset } => {
                let n = *value;
                if n.fract() != 0.0 || n < 1.0 || n > columns.len() as f64 {
                    return Err(SqlError::new(
                        format!(
                            "ORDER BY position {n} is out of range (1..={})",
                            columns.len()
                        ),
                        *offset,
                    ));
                }
                n as usize - 1
            }
            Expr::Column { table: None, name, offset } if columns.iter().any(|c| c == name) => {
                columns
                    .iter()
                    .position(|c| c == name)
                    .ok_or_else(|| SqlError::new("unreachable", *offset))?
            }
            other => {
                // Structural match against a select item's render.
                let rendered = match &mode {
                    Mode::Project { .. } => az.scalar(other)?.render(),
                    Mode::Aggregate { keys, aggs, .. } => {
                        let mut ob = OutBuilder {
                            az: &az,
                            keys: keys.clone(),
                            key_renders: keys.iter().map(Scalar::render).collect(),
                            aggs: aggs.clone(),
                            agg_renders: aggs.iter().map(Aggregate::render).collect(),
                        };
                        let out = ob.convert(other)?;
                        if ob.aggs.len() != aggs.len() {
                            return Err(SqlError::new(
                                "ORDER BY expression must appear in the SELECT list",
                                other.offset(),
                            ));
                        }
                        out.render(&ob.keys, &ob.aggs)
                    }
                };
                select_renders.iter().position(|r| *r == rendered).ok_or_else(|| {
                    SqlError::new(
                        "ORDER BY expression must appear in the SELECT list",
                        other.offset(),
                    )
                })?
            }
        };
        order_by.push((idx, item.desc));
    }

    Ok(LogicalPlan {
        fact: TableScan::new(fact_table),
        join,
        filter,
        mode,
        having,
        columns,
        int_outputs,
        order_by,
        limit: q.limit,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sql::parse::parse;

    fn plan(text: &str) -> LogicalPlan {
        analyze(&parse(text).unwrap().query).unwrap()
    }

    fn plan_err(text: &str) -> SqlError {
        analyze(&parse(text).unwrap().query).unwrap_err()
    }

    #[test]
    fn resolves_tables_aliases_and_derived_columns() {
        let p = plan(
            "SELECT w.bucket, COUNT(*) FROM trips t JOIN weather w ON t.day = w.day \
             GROUP BY w.bucket",
        );
        assert_eq!(p.fact.table, Table::Trips);
        let j = p.join.as_ref().unwrap();
        assert_eq!(j.dim.table, Table::Weather);
        assert_eq!(j.fact_key, Scalar::Col(Column::Day));
        assert_eq!(j.dim_key, Scalar::Col(Column::WeatherDay));
        let Mode::Aggregate { keys, aggs, select } = &p.mode else { panic!() };
        assert_eq!(keys, &[Scalar::Col(Column::Bucket)]);
        assert_eq!(aggs.len(), 1);
        assert_eq!(select, &[OutExpr::Key(0), OutExpr::Agg(0)]);
        assert_eq!(p.int_outputs, vec![true, true]);
    }

    #[test]
    fn reversed_join_condition_normalizes_sides() {
        let p = plan("SELECT COUNT(*) FROM trips t JOIN weather w ON w.day = t.day");
        let j = p.join.unwrap();
        assert_eq!(j.fact_key, Scalar::Col(Column::Day));
        assert_eq!(j.dim_key, Scalar::Col(Column::WeatherDay));
    }

    #[test]
    fn where_splits_into_conjuncts() {
        let p = plan(
            "SELECT hour FROM trips WHERE tip_amount > 10 AND day BETWEEN 5 AND 9 AND hour = 3",
        );
        assert_eq!(p.filter.len(), 3);
        // Nothing pushed before the rewriter runs.
        assert!(p.fact.pushed.is_empty());
        assert!(p.fact.day_ranges().is_empty());
        assert!(p.fact.projected.is_none());
    }

    #[test]
    fn shared_aggregates_dedupe_and_arithmetic_over_them_works() {
        let p = plan(
            "SELECT month, SUM(credit) / COUNT(*), COUNT(*) FROM trips GROUP BY month",
        );
        let Mode::Aggregate { aggs, select, .. } = &p.mode else { panic!() };
        assert_eq!(aggs.len(), 2, "COUNT(*) shared: {aggs:?}");
        let OutExpr::Bin(BinOp::Div, l, r) = &select[1] else { panic!("{select:?}") };
        assert_eq!(**l, OutExpr::Agg(0));
        assert_eq!(**r, OutExpr::Agg(1));
        assert_eq!(select[2], OutExpr::Agg(1));
        assert_eq!(p.int_outputs, vec![true, false, true]);
    }

    #[test]
    fn order_by_position_alias_and_expression() {
        let p = plan("SELECT hour, COUNT(*) AS n FROM trips GROUP BY hour ORDER BY n DESC, 1");
        assert_eq!(p.order_by, vec![(1, true), (0, false)]);
        let p = plan("SELECT hour, COUNT(*) FROM trips GROUP BY hour ORDER BY COUNT(*) DESC");
        assert_eq!(p.order_by, vec![(1, true)]);
    }

    #[test]
    fn error_paths_carry_offsets() {
        let e = plan_err("SELECT x FROM nowhere");
        assert!(e.message.contains("unknown table"), "{e}");
        assert_eq!(e.offset, 14);
        let e = plan_err("SELECT nope FROM trips");
        assert!(e.message.contains("unknown column"), "{e}");
        let e = plan_err("SELECT day FROM trips t JOIN weather w ON t.day = w.day");
        assert!(e.message.contains("ambiguous"), "{e}");
        let e = plan_err("SELECT hour FROM trips GROUP BY month");
        assert!(e.message.contains("GROUP BY"), "{e}");
        let e = plan_err("SELECT COUNT(*) FROM trips WHERE COUNT(*) > 1");
        assert!(e.message.contains("WHERE"), "{e}");
        let e = plan_err("SELECT COUNT(*) FROM trips t JOIN weather w ON t.day < w.day");
        assert!(e.message.contains("equality"), "{e}");
        let e = plan_err("SELECT t1.day FROM trips t1 JOIN trips t2 ON t1.day = t2.day");
        assert!(e.message.contains("self-join"), "{e}");
        let e = plan_err("SELECT hour FROM trips ORDER BY tip_amount");
        assert!(e.message.contains("SELECT list"), "{e}");
    }

    #[test]
    fn select_star_expands_catalog_order() {
        let p = plan("SELECT * FROM trips");
        let Mode::Project { exprs } = &p.mode else { panic!() };
        assert_eq!(exprs.len(), Table::Trips.columns().len());
        assert_eq!(p.columns[0], "taxi_type");
        let p = plan("SELECT * FROM trips t JOIN weather w ON t.day = w.day");
        let Mode::Project { exprs } = &p.mode else { panic!() };
        assert_eq!(
            exprs.len(),
            Table::Trips.columns().len() + Table::Weather.columns().len()
        );
    }

    #[test]
    fn scalar_eval_and_typing() {
        let s = plan("SELECT tip_amount / trip_distance FROM trips");
        let Mode::Project { exprs } = &s.mode else { panic!() };
        let v = exprs[0].eval(&|c| match c {
            Column::TipAmount => 6.0,
            Column::TripDistance => 3.0,
            _ => 0.0,
        });
        assert_eq!(v, 2.0);
        assert_eq!(s.int_outputs, vec![false]);

        let s = plan("SELECT hour + 1 FROM trips WHERE NOT (hour = 3 OR hour > 20)");
        assert!(s.filter[0].test(&|_| 4.0));
        assert!(!s.filter[0].test(&|_| 3.0));
        assert!(!s.filter[0].test(&|_| 21.0));
        assert_eq!(s.int_outputs, vec![true]);
    }
}
