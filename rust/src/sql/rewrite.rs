//! Rule-based logical rewrites, applied in order when
//! `flint.sql.optimizer = on`:
//!
//! 1. **Constant folding** — any expression subtree without a column
//!    reference collapses to a literal.
//! 2. **Predicate pushdown** — WHERE conjuncts referencing a single
//!    table move below the join into that table's scan (always-true
//!    conjuncts are dropped outright).
//! 3. **Day-range extraction** — pushed trip conjuncts of the shape
//!    `day/month <cmp> literal` or `day/month BETWEEN a AND b` become
//!    typed day ranges. These lower to [`crate::plan::DynOp::DayRange`]
//!    ops, which the engine's stats-based pruning (`flint.scan.prune`)
//!    can skip whole splits with — an opaque closure never prunes.
//!    `month` converts exactly: month boundaries align with day
//!    boundaries, so `month BETWEEN a AND b` is the day interval
//!    `[first_day(a), last_day(b)]`.
//! 4. **Projection pushdown** — each scan materializes only the
//!    columns the plan references above it.

use crate::data::chrono::days_from_civil;
use crate::sql::logical::{Column, LogicalPlan, Mode, PushedPred, Scalar, Table, TableScan};
use crate::sql::parse::BinOp;

/// Apply every rewrite rule, producing the optimized logical plan.
pub fn rewrite(plan: &LogicalPlan) -> LogicalPlan {
    let mut p = plan.clone();
    fold_plan(&mut p);
    push_predicates(&mut p);
    extract_day_ranges(&mut p.fact);
    if let Some(j) = &mut p.join {
        extract_day_ranges(&mut j.dim);
    }
    push_projection(&mut p);
    p
}

// ---------------------------------------------------------------------
// Constant folding
// ---------------------------------------------------------------------

/// Fold one scalar bottom-up: constant subtrees evaluate to literals.
pub fn fold(s: &Scalar) -> Scalar {
    let folded = match s {
        Scalar::Col(_) | Scalar::LitI(_) | Scalar::LitF(_) => s.clone(),
        Scalar::Neg(e) => Scalar::Neg(Box::new(fold(e))),
        Scalar::Not(e) => Scalar::Not(Box::new(fold(e))),
        Scalar::Bin(op, l, r) => Scalar::Bin(*op, Box::new(fold(l)), Box::new(fold(r))),
        Scalar::Between(e, lo, hi) => {
            Scalar::Between(Box::new(fold(e)), Box::new(fold(lo)), Box::new(fold(hi)))
        }
    };
    if matches!(folded, Scalar::Col(_) | Scalar::LitI(_) | Scalar::LitF(_)) {
        return folded;
    }
    if folded.is_const() {
        let v = folded.eval(&|_| 0.0);
        if v.is_finite() {
            return Scalar::lit(v);
        }
    }
    folded
}

fn fold_plan(p: &mut LogicalPlan) {
    for pred in &mut p.filter {
        *pred = fold(pred);
    }
    if let Some(j) = &mut p.join {
        j.fact_key = fold(&j.fact_key);
        j.dim_key = fold(&j.dim_key);
    }
    match &mut p.mode {
        Mode::Project { exprs } => {
            for e in exprs {
                *e = fold(e);
            }
        }
        Mode::Aggregate { keys, aggs, .. } => {
            for k in keys {
                *k = fold(k);
            }
            for a in aggs {
                if let Some(arg) = &mut a.arg {
                    *arg = fold(arg);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Predicate pushdown
// ---------------------------------------------------------------------

fn push_predicates(p: &mut LogicalPlan) {
    let dim_name = p.join.as_ref().map(|j| j.dim.table.name());
    let mut residual = Vec::new();
    for pred in p.filter.drain(..) {
        // An always-true conjunct disappears; an always-false one is
        // pushed like any other (the scan then emits nothing).
        if pred == Scalar::LitI(1) {
            continue;
        }
        let tables = pred.tables();
        let single = tables.len() <= 1;
        let touches_dim = dim_name.is_some_and(|d| tables.contains(d));
        if single && !touches_dim {
            p.fact.pushed.push(PushedPred::Generic(pred));
        } else if single && touches_dim {
            p.join
                .as_mut()
                .expect("dim conjunct implies a join")
                .dim
                .pushed
                .push(PushedPred::Generic(pred));
        } else {
            residual.push(pred);
        }
    }
    p.filter = residual;
}

// ---------------------------------------------------------------------
// Day-range extraction
// ---------------------------------------------------------------------

/// First day index of month-index `m` (months since Jan 2009).
fn first_day_of_month(m: i64) -> i64 {
    let y = 2009 + m.div_euclid(12);
    let mo = (m.rem_euclid(12) + 1) as u32;
    days_from_civil(y, mo, 1) - days_from_civil(2009, 1, 1)
}

/// Clamp an `f64`/`i64` bound into day-index space.
fn clamp_day(v: i64) -> i32 {
    v.clamp(i32::MIN as i64, i32::MAX as i64) as i32
}

/// Inclusive integer interval implied by `col <cmp> value` on an
/// integer column: `(lo, hi)` with `i64::MIN`/`MAX` for unbounded.
fn int_bounds(op: BinOp, v: f64, col_on_left: bool) -> Option<(i64, i64)> {
    // Normalize to `col <op> v`.
    let op = if col_on_left {
        op
    } else {
        match op {
            BinOp::Lt => BinOp::Gt,
            BinOp::Le => BinOp::Ge,
            BinOp::Gt => BinOp::Lt,
            BinOp::Ge => BinOp::Le,
            other => other,
        }
    };
    Some(match op {
        BinOp::Eq => {
            if v.fract() == 0.0 {
                (v as i64, v as i64)
            } else {
                (1, 0) // unsatisfiable on an integer column
            }
        }
        BinOp::Ge => (v.ceil() as i64, i64::MAX),
        BinOp::Gt => (v.floor() as i64 + 1, i64::MAX),
        BinOp::Le => (i64::MIN, v.floor() as i64),
        BinOp::Lt => (i64::MIN, v.ceil() as i64 - 1),
        _ => return None,
    })
}

fn const_val(s: &Scalar) -> Option<f64> {
    match s {
        Scalar::LitI(v) => Some(*v as f64),
        Scalar::LitF(v) => Some(*v),
        _ => None,
    }
}

/// The day range equivalent to a (folded) trips conjunct, if it is a
/// `day`/`month` range pattern over a bare column.
fn day_range_of(pred: &Scalar) -> Option<(i32, i32)> {
    let to_days = |col: Column, lo: i64, hi: i64| -> (i32, i32) {
        match col {
            Column::Day => (clamp_day(lo), clamp_day(hi)),
            Column::Month => {
                let lo = if lo == i64::MIN { i64::MIN } else { first_day_of_month(lo) };
                let hi = if hi == i64::MAX { i64::MAX } else { first_day_of_month(hi + 1) - 1 };
                (clamp_day(lo), clamp_day(hi))
            }
            _ => unreachable!(),
        }
    };
    match pred {
        Scalar::Between(e, lo, hi) => {
            let Scalar::Col(col @ (Column::Day | Column::Month)) = **e else { return None };
            let (a, b) = (const_val(lo)?, const_val(hi)?);
            let (lo1, _) = int_bounds(BinOp::Ge, a, true)?;
            let (_, hi1) = int_bounds(BinOp::Le, b, true)?;
            Some(to_days(col, lo1, hi1))
        }
        Scalar::Bin(op, l, r) if op.is_comparison() && *op != BinOp::NotEq => {
            let (col, v, col_on_left) = match (&**l, &**r) {
                (Scalar::Col(c @ (Column::Day | Column::Month)), rhs) => {
                    (*c, const_val(rhs)?, true)
                }
                (lhs, Scalar::Col(c @ (Column::Day | Column::Month))) => {
                    (*c, const_val(lhs)?, false)
                }
                _ => return None,
            };
            let (lo, hi) = int_bounds(*op, v, col_on_left)?;
            Some(to_days(col, lo, hi))
        }
        _ => None,
    }
}

fn extract_day_ranges(scan: &mut TableScan) {
    if scan.table != Table::Trips {
        return;
    }
    for pred in &mut scan.pushed {
        if let PushedPred::Generic(s) = pred {
            if let Some((lo, hi)) = day_range_of(s) {
                *pred = PushedPred::DayRange { lo, hi };
            }
        }
    }
}

// ---------------------------------------------------------------------
// Projection pushdown
// ---------------------------------------------------------------------

fn push_projection(p: &mut LogicalPlan) {
    let fact_cols = p.referenced_columns(p.fact.table);
    let dim_cols = p.join.as_ref().map(|j| p.referenced_columns(j.dim.table));
    p.fact.projected = Some(fact_cols);
    if let (Some(j), Some(cols)) = (p.join.as_mut(), dim_cols) {
        j.dim.projected = Some(cols);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sql::logical::analyze;
    use crate::sql::parse::parse;

    fn optimized(text: &str) -> LogicalPlan {
        rewrite(&analyze(&parse(text).unwrap().query).unwrap())
    }

    #[test]
    fn folds_constants() {
        let p = optimized("SELECT tip_amount + (2 * 3 + 4) FROM trips WHERE 1 + 1 = 2");
        let Mode::Project { exprs } = &p.mode else { panic!() };
        assert_eq!(exprs[0], Scalar::Bin(
            BinOp::Add,
            Box::new(Scalar::Col(Column::TipAmount)),
            Box::new(Scalar::LitI(10)),
        ));
        // The always-true WHERE conjunct folded away entirely.
        assert!(p.filter.is_empty());
        assert!(p.fact.pushed.is_empty());
    }

    #[test]
    fn pushes_single_table_conjuncts_below_the_join() {
        let p = optimized(
            "SELECT COUNT(*) FROM trips t JOIN weather w ON t.day = w.day \
             WHERE t.tip_amount > 5 AND w.precip > 0.1 AND t.fare_amount > w.precip",
        );
        assert_eq!(p.fact.pushed.len(), 1, "{:?}", p.fact.pushed);
        let j = p.join.as_ref().unwrap();
        assert_eq!(j.dim.pushed.len(), 1, "{:?}", j.dim.pushed);
        // The cross-table conjunct stays above the join.
        assert_eq!(p.filter.len(), 1, "{:?}", p.filter);
    }

    #[test]
    fn extracts_day_and_month_ranges() {
        let p = optimized("SELECT COUNT(*) FROM trips WHERE day BETWEEN 100 AND 200");
        assert_eq!(p.fact.day_ranges(), vec![(100, 200)]);
        assert!(p.fact.generic_preds().is_empty());

        let p = optimized("SELECT COUNT(*) FROM trips WHERE day >= 10.5 AND day < 20");
        assert_eq!(p.fact.day_ranges(), vec![(11, i32::MAX), (i32::MIN, 19)]);

        // month 0 = Jan 2009 (days 0..=30), month 1 = Feb 2009 (31..=58).
        let p = optimized("SELECT COUNT(*) FROM trips WHERE month = 0");
        assert_eq!(p.fact.day_ranges(), vec![(0, 30)]);
        let p = optimized("SELECT COUNT(*) FROM trips WHERE month BETWEEN 0 AND 1");
        assert_eq!(p.fact.day_ranges(), vec![(0, 58)]);

        // Equality on a fractional literal can never hold on an int column.
        let p = optimized("SELECT COUNT(*) FROM trips WHERE day = 10.5");
        let ranges = p.fact.day_ranges();
        assert_eq!(ranges.len(), 1);
        assert!(ranges[0].0 > ranges[0].1, "unsatisfiable range prunes everything");

        // A range mixed with an opaque conjunct still extracts, and the
        // WHERE source order is preserved — the opaque conjunct lowers
        // to a Filter op *ahead of* the DayRange op, the exact chain
        // shape the leading_day_range commute fix keeps prunable.
        let p = optimized(
            "SELECT COUNT(*) FROM trips WHERE tip_amount > 5 AND day BETWEEN 100 AND 200",
        );
        assert_eq!(p.fact.pushed.len(), 2);
        assert!(matches!(p.fact.pushed[0], PushedPred::Generic(_)));
        assert!(matches!(p.fact.pushed[1], PushedPred::DayRange { lo: 100, hi: 200 }));
    }

    #[test]
    fn projection_pushdown_narrows_scans() {
        let p = optimized(
            "SELECT hour, COUNT(*) FROM trips WHERE tip_amount > 10 GROUP BY hour",
        );
        assert_eq!(
            p.fact.projected,
            Some(vec![Column::Hour, Column::TipAmount]),
        );

        let p = optimized(
            "SELECT w.bucket, COUNT(*) FROM trips t JOIN weather w ON t.day = w.day \
             GROUP BY w.bucket",
        );
        assert_eq!(p.fact.projected, Some(vec![Column::Day]));
        assert_eq!(
            p.join.unwrap().dim.projected,
            Some(vec![Column::WeatherDay, Column::Bucket]),
        );

        // COUNT(*) alone needs no columns at all.
        let p = optimized("SELECT COUNT(*) FROM trips");
        assert_eq!(p.fact.projected, Some(Vec::new()));
    }

    #[test]
    fn day_range_semantics_match_generic_eval() {
        // The extracted range must accept exactly the days the original
        // predicate accepts — spot-check across the patterns.
        for (sql, pred) in [
            ("day BETWEEN 100 AND 200", None),
            ("day > 99.5", None),
            ("day <= 0", None),
            ("month = 3", None),
            ("month >= 88", None),
            ("month < 2", None),
            ("NOT day > 10", Some(())), // not a range pattern — must NOT extract
        ] {
            let p = optimized(&format!("SELECT COUNT(*) FROM trips WHERE {sql}"));
            if pred.is_some() {
                assert!(p.fact.day_ranges().is_empty(), "{sql} must not extract");
                continue;
            }
            let ranges = p.fact.day_ranges();
            assert_eq!(ranges.len(), 1, "{sql}");
            let (lo, hi) = ranges[0];
            let original = analyze(
                &parse(&format!("SELECT COUNT(*) FROM trips WHERE {sql}")).unwrap().query,
            )
            .unwrap()
            .filter
            .remove(0);
            for day in -5..NUM_DAYS_TEST {
                let month = month_of_day(day);
                let in_range = day >= lo && day <= hi;
                let keeps = original.test(&|c| match c {
                    Column::Day => day as f64,
                    Column::Month => month as f64,
                    _ => 0.0,
                });
                assert_eq!(in_range, keeps, "{sql} at day {day}");
            }
        }
    }

    const NUM_DAYS_TEST: i32 = 2750;

    fn month_of_day(day: i32) -> i32 {
        let days = days_from_civil(2009, 1, 1) + day as i64;
        let (y, m, _) = crate::data::chrono::civil_from_days(days);
        ((y - 2009) * 12 + m as i64 - 1) as i32
    }
}
