//! The SQL frontend: a hand-rolled lexer/parser, a typed logical plan
//! over the taxi/weather schemas, a rule-based rewriter (predicate
//! pushdown, projection pushdown, constant folding) and a cost-based
//! physical planner — all lowering onto the generic [`Rdd`] lineage
//! API, so SQL queries compile to the same stage DAGs, run on the same
//! schedulers (barrier or pipelined, with speculation), shuffle through
//! the same backends, and bill the same cost ledgers as hand-built
//! driver programs.
//!
//! ```text
//! let job = sql::compile(&sc, "SELECT hour, COUNT(*) FROM trips \
//!                              WHERE tip_amount > 10 GROUP BY hour")?;
//! println!("{}", job.explain_text());   // logical → optimized → physical
//! let result = job.collect()?;          // runs serverlessly
//! ```
//!
//! Entry points: [`crate::exec::FlintContext::sql`] (and `EXPLAIN …`),
//! [`crate::exec::service::FlintService::submit_sql`], and the
//! `flint sql "<query>"` CLI.

pub mod lex;
pub mod logical;
pub mod parse;
pub mod physical;
pub mod rewrite;

pub use lex::SqlError;
pub use logical::LogicalPlan;
pub use physical::{JoinStrategy, PhysicalChoice};

use crate::compute::queries::QueryId;
use crate::compute::value::Value;
use crate::exec::FlintContext;
use crate::plan::Rdd;
use anyhow::Result;
use std::cmp::Ordering;
use std::fmt::Write as _;

/// A compiled SQL query: the lowered lineage plus everything needed to
/// shape driver-side output (names, types, ORDER BY / LIMIT) and to
/// render EXPLAIN.
pub struct SqlJob {
    pub sql: String,
    /// The statement was `EXPLAIN SELECT …`.
    pub is_explain: bool,
    /// The lowered lineage, bound to the compiling session.
    pub rdd: Rdd,
    pub columns: Vec<String>,
    pub int_outputs: Vec<bool>,
    order_by: Vec<(usize, bool)>,
    limit: Option<usize>,
    /// The plan as analyzed, before any rewriting.
    pub logical: LogicalPlan,
    /// The plan after rewriting + physical reordering (what was lowered).
    pub optimized: LogicalPlan,
    pub choice: PhysicalChoice,
}

/// A finished SQL query: named columns and driver-ordered rows.
#[derive(Debug, Clone, PartialEq)]
pub struct SqlResult {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<Value>>,
}

impl SqlResult {
    /// Render as an aligned text table (the CLI's output format).
    pub fn render(&self) -> String {
        let cells: Vec<Vec<String>> = std::iter::once(self.columns.clone())
            .chain(self.rows.iter().map(|r| r.iter().map(render_value).collect()))
            .collect();
        let ncols = cells.iter().map(Vec::len).max().unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        for row in &cells {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        for (idx, row) in cells.iter().enumerate() {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
            if idx == 0 {
                let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1))));
            }
        }
        out
    }
}

fn render_value(v: &Value) -> String {
    match v {
        Value::I64(n) => n.to_string(),
        Value::F64(f) => format!("{f:.4}"),
        Value::Str(s) => s.clone(),
        Value::Bool(b) => b.to_string(),
        Value::Null => "NULL".to_string(),
        other => format!("{other:?}"),
    }
}

fn cmp_rows(a: &[Value], b: &[Value]) -> Ordering {
    for (x, y) in a.iter().zip(b) {
        let o = x.total_cmp(y);
        if o != Ordering::Equal {
            return o;
        }
    }
    a.len().cmp(&b.len())
}

impl SqlJob {
    /// Shape raw collected values into final rows: a deterministic base
    /// order (engines return rows in partition order), then the
    /// query's ORDER BY (stable, so untouched columns keep the base
    /// order as tiebreak), then LIMIT.
    pub fn shape(&self, collected: Vec<Value>) -> Vec<Vec<Value>> {
        let mut rows: Vec<Vec<Value>> = collected
            .into_iter()
            .filter_map(|v| match v {
                Value::List(cells) => Some(cells),
                _ => None,
            })
            .collect();
        rows.sort_by(|a, b| cmp_rows(a, b));
        if !self.order_by.is_empty() {
            let keys = self.order_by.clone();
            rows.sort_by(|a, b| {
                for (i, desc) in &keys {
                    let av = a.get(*i).and_then(Value::as_f64).unwrap_or(f64::NAN);
                    let bv = b.get(*i).and_then(Value::as_f64).unwrap_or(f64::NAN);
                    let o = av.total_cmp(&bv);
                    let o = if *desc { o.reverse() } else { o };
                    if o != Ordering::Equal {
                        return o;
                    }
                }
                Ordering::Equal
            });
        }
        if let Some(n) = self.limit {
            rows.truncate(n);
        }
        rows
    }

    /// Run the query on its session and shape the result.
    pub fn collect(&self) -> Result<SqlResult> {
        let values = self.rdd.collect()?;
        Ok(SqlResult { columns: self.columns.clone(), rows: self.shape(values) })
    }

    /// The full EXPLAIN rendering: query, logical plan, optimized plan,
    /// physical decisions, and the compiled stage DAG.
    pub fn explain_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== SQL ==\n{}\n", self.sql.trim());
        let _ = writeln!(out, "== Logical Plan ==\n{}", self.logical.render());
        let _ = writeln!(out, "== Optimized Plan ==\n{}", self.optimized.render());
        let _ = writeln!(out, "== Physical ==\n{}{}", self.choice.render(), self.rdd.explain());
        out
    }
}

/// Compile `text` against a session: parse → analyze → (optionally)
/// rewrite → cost-based physical planning → lower to lineage. With
/// `flint.sql.optimizer = off` the analyzed plan lowers as-is: full
/// column parse, no pushdown, shuffle join, default partition counts.
pub fn compile(sc: &FlintContext, text: &str) -> Result<SqlJob, SqlError> {
    let stmt = parse::parse(text)?;
    let logical = logical::analyze(&stmt.query)?;
    let optimizer = sc.env().config().flint.sql.optimizer;
    let rewritten = if optimizer { rewrite::rewrite(&logical) } else { logical.clone() };
    let (plan, choice) = physical::plan_physical(sc, &rewritten, optimizer);
    let rdd = physical::build_rdd(sc, &plan, &choice)?;
    Ok(SqlJob {
        sql: text.to_string(),
        is_explain: stmt.explain,
        rdd,
        columns: plan.columns.clone(),
        int_outputs: plan.int_outputs.clone(),
        order_by: plan.order_by.clone(),
        limit: plan.limit,
        logical,
        optimized: plan,
        choice,
    })
}

/// The paper's Table I queries (plus Q6J) expressed as SQL. Q6 and Q6J
/// share one text — Q6J is Q6 compiled with
/// `flint.sql.broadcast_threshold_bytes = 0`, which forces the join
/// through the shuffle exactly like the hand-built Q6J plan.
pub fn table1_sql(q: QueryId) -> &'static str {
    match q {
        QueryId::Q0 => "SELECT COUNT(*) FROM trips",
        QueryId::Q1 => {
            "SELECT hour, COUNT(*) FROM trips \
             WHERE dropoff_lon BETWEEN -74.0156 AND -74.0138 \
             AND dropoff_lat BETWEEN 40.7139 AND 40.7155 \
             GROUP BY hour ORDER BY hour"
        }
        QueryId::Q2 => {
            "SELECT hour, COUNT(*) FROM trips \
             WHERE dropoff_lon BETWEEN -74.0124 AND -74.0106 \
             AND dropoff_lat BETWEEN 40.7189 AND 40.7205 \
             GROUP BY hour ORDER BY hour"
        }
        QueryId::Q3 => {
            "SELECT hour, COUNT(*) FROM trips \
             WHERE dropoff_lon BETWEEN -74.0156 AND -74.0138 \
             AND dropoff_lat BETWEEN 40.7139 AND 40.7155 \
             AND tip_amount > 10 \
             GROUP BY hour ORDER BY hour"
        }
        QueryId::Q4 => {
            "SELECT month, SUM(credit), COUNT(*) FROM trips \
             GROUP BY month ORDER BY month"
        }
        QueryId::Q5 => {
            "SELECT month, taxi_type, COUNT(*) FROM trips \
             GROUP BY month, taxi_type ORDER BY month, taxi_type"
        }
        QueryId::Q6 | QueryId::Q6J => {
            "SELECT w.bucket, COUNT(*) FROM trips t \
             JOIN weather w ON t.day = w.day \
             GROUP BY w.bucket ORDER BY w.bucket"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_corpus_parses_and_analyzes() {
        for q in QueryId::ALL_WITH_JOINS {
            let text = table1_sql(q);
            let stmt = parse::parse(text).unwrap_or_else(|e| panic!("{q:?}: {e}"));
            let plan = logical::analyze(&stmt.query).unwrap_or_else(|e| panic!("{q:?}: {e}"));
            let _ = rewrite::rewrite(&plan);
        }
    }

    #[test]
    fn result_rendering_aligns() {
        let r = SqlResult {
            columns: vec!["hour".to_string(), "count(*)".to_string()],
            rows: vec![
                vec![Value::I64(7), Value::I64(1234)],
                vec![Value::I64(18), Value::I64(9)],
            ],
        };
        let text = r.render();
        assert!(text.contains("hour"));
        assert!(text.contains("1234"));
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "{text}");
    }

    #[test]
    fn row_sorting_is_total_and_stable() {
        let rows = vec![
            Value::List(vec![Value::I64(2), Value::I64(10)]),
            Value::List(vec![Value::I64(1), Value::I64(20)]),
            Value::Null, // malformed entries drop
        ];
        let ordered: Vec<Vec<Value>> = {
            let mut rs: Vec<Vec<Value>> = rows
                .into_iter()
                .filter_map(|v| match v {
                    Value::List(c) => Some(c),
                    _ => None,
                })
                .collect();
            rs.sort_by(|a, b| cmp_rows(a, b));
            rs
        };
        assert_eq!(ordered[0][0], Value::I64(1));
        assert_eq!(ordered[1][0], Value::I64(2));
    }
}
