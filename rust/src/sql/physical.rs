//! The cost-based physical planner and the lowering onto the generic
//! [`Rdd`] lineage API.
//!
//! Physical decisions, made from table-size estimates (summing each
//! source's input-split bytes) against the simulator's own cost
//! constants:
//!
//! - **Join strategy** — broadcast (build the dimension table at the
//!   driver, ship it inside the probe side's map closure) vs shuffle
//!   (hash-partition both sides through the shuffle backend). The cost
//!   model mirrors the A5 `join_crossover` study: broadcast pays a
//!   per-map-wave read of the build table, shuffle pays an extra
//!   full-table hop through the shuffle backend plus two extra stages.
//!   `flint.sql.broadcast_threshold_bytes` caps broadcast eligibility
//!   (0 forces every join through the shuffle — how Q6J is expressed).
//! - **Join order** — the smaller estimated side becomes the build
//!   side, whichever side of the JOIN it was written on.
//! - **Partition counts** — shuffle widths are clamped to the
//!   estimated distinct-key counts instead of always using
//!   `flint.default_shuffle_partitions`.
//!
//! Lowering produces ordinary lineage — `text_file → (DayRange |
//! Filter)* → flat_map(parse) → [join] → reduce_by_key → map` — so the
//! DAG compiler, both schedulers, speculation, and the multi-tenant
//! service run SQL exactly like any hand-built RDD program.

use crate::compute::value::Value;
use crate::config::FlintConfig;
use crate::data::chrono::{day_index, hour_of_day, month_index, parse_datetime};
use crate::data::schema::{NUM_COLUMNS, PAYMENT_CREDIT};
use crate::data::weather::precip_bucket;
use crate::exec::FlintContext;
use crate::plan::Rdd;
use crate::sql::lex::SqlError;
use crate::sql::logical::{
    Aggregate, Column, LogicalPlan, Mode, PushedPred, Scalar, Table, TableScan,
};
use crate::sql::parse::AggFunc;
use std::collections::HashMap;
use std::sync::Arc;

// ---------------------------------------------------------------------
// Row parsing (projection-aware, both tables)
// ---------------------------------------------------------------------

/// Parse a raw trips CSV line into the values of `cols`, in layout
/// order. Structurally malformed lines (wrong column count, unparsable
/// referenced field) yield `None` and are dropped — the same contract
/// as the kernel path's projected parse.
pub fn parse_trip_row(line: &str, cols: &[Column]) -> Option<Vec<Value>> {
    let mut fields = [""; NUM_COLUMNS];
    let mut n = 0;
    for f in line.split(',') {
        if n == NUM_COLUMNS {
            return None;
        }
        fields[n] = f;
        n += 1;
    }
    if n != NUM_COLUMNS {
        return None;
    }
    let needs_time =
        cols.iter().any(|c| matches!(c, Column::Day | Column::Month | Column::Hour));
    let ts = if needs_time { Some(parse_datetime(fields[2].as_bytes())?) } else { None };
    let mut out = Vec::with_capacity(cols.len());
    for c in cols {
        let int = |i: usize| fields[i].parse::<i64>().ok().map(Value::I64);
        let float = |i: usize| fields[i].parse::<f64>().ok().map(Value::F64);
        out.push(match c {
            Column::TaxiType => int(0)?,
            Column::Day => Value::I64(day_index(ts?) as i64),
            Column::Month => Value::I64(month_index(ts?) as i64),
            Column::Hour => Value::I64(hour_of_day(ts?) as i64),
            Column::PassengerCount => int(3)?,
            Column::TripDistance => float(4)?,
            Column::PickupLon => float(5)?,
            Column::PickupLat => float(6)?,
            Column::DropoffLon => float(7)?,
            Column::DropoffLat => float(8)?,
            Column::PaymentType => int(9)?,
            Column::Credit => {
                Value::I64(i64::from(fields[9].parse::<i64>().ok()? == PAYMENT_CREDIT as i64))
            }
            Column::FareAmount => float(10)?,
            Column::TipAmount => float(11)?,
            Column::TotalAmount => float(12)?,
            Column::WeatherDay | Column::Precip | Column::Bucket => return None,
        });
    }
    Some(out)
}

/// Parse a `day_index,precip` weather line into the values of `cols`.
pub fn parse_weather_row(line: &str, cols: &[Column]) -> Option<Vec<Value>> {
    let (d, p) = line.split_once(',')?;
    let day: i64 = d.trim().parse().ok()?;
    let precip: f64 = p.trim().parse().ok()?;
    let mut out = Vec::with_capacity(cols.len());
    for c in cols {
        out.push(match c {
            Column::WeatherDay => Value::I64(day),
            Column::Precip => Value::F64(precip),
            Column::Bucket => Value::I64(precip_bucket(precip as f32) as i64),
            _ => return None,
        });
    }
    Some(out)
}

pub fn parse_row(table: Table, line: &str, cols: &[Column]) -> Option<Vec<Value>> {
    match table {
        Table::Trips => parse_trip_row(line, cols),
        Table::Weather => parse_weather_row(line, cols),
    }
}

/// A row accessor over a parsed layout, for [`Scalar::eval`]. Missing
/// columns read as NaN (every comparison on them is false).
fn col_accessor<'a>(layout: &'a [Column], cells: &'a [Value]) -> impl Fn(Column) -> f64 + 'a {
    move |c| {
        layout
            .iter()
            .position(|x| *x == c)
            .and_then(|i| cells.get(i))
            .and_then(Value::as_f64)
            .unwrap_or(f64::NAN)
    }
}

/// Canonical `-0.0 -> 0.0` so float keys hash identically on both join
/// sides (`Value::stable_hash` is bit-based).
fn norm(v: f64) -> f64 {
    if v == 0.0 {
        0.0
    } else {
        v
    }
}

fn key_value(int_key: bool, v: f64) -> Value {
    if int_key {
        Value::I64(v as i64)
    } else {
        Value::F64(norm(v))
    }
}

fn out_value(int: bool, v: f64) -> Value {
    if int && v.is_finite() {
        Value::I64(v as i64)
    } else {
        Value::F64(norm(v))
    }
}

/// Does a raw trips line fall inside an inclusive day range? (The
/// driver-side mirror of [`crate::plan::DynOp::DayRange`].)
fn line_in_day_range(line: &str, lo: i32, hi: i32) -> bool {
    line.split(',')
        .nth(2)
        .and_then(|f| parse_datetime(f.as_bytes()))
        .map(day_index)
        .is_some_and(|d| (lo..=hi).contains(&d))
}

// ---------------------------------------------------------------------
// Cost model (calibrated against the A5 join_crossover study)
// ---------------------------------------------------------------------

/// Average bytes of one trips CSV row (the generator produces ~131).
const TRIP_ROW_BYTES: f64 = 131.0;
/// Encoded bytes of one shuffled `(key, row)` pair on the join edge.
const SHUFFLED_PAIR_BYTES: f64 = 24.0;

/// Extra latency a broadcast join adds over a plain scan: every wave of
/// probe-side map tasks reads the whole build table from S3 before it
/// can join (A5's Q6 path — per-task GETs of the dimension table).
pub fn broadcast_join_cost_s(cfg: &FlintConfig, probe_bytes: u64, build_bytes: u64) -> f64 {
    let sim = &cfg.sim;
    let tasks = (probe_bytes as f64 / cfg.flint.input_split_bytes as f64).ceil().max(1.0);
    let waves = (tasks / sim.max_concurrency.max(1) as f64).ceil().max(1.0);
    waves * (sim.s3_first_byte_s + build_bytes as f64 / (sim.s3_flint_mbps * 1e6))
}

/// Extra latency a shuffle join adds: two extra stages (build-side
/// scan + join) on the schedule, the build-side scan itself, and one
/// full probe-side hop through the shuffle backend (every probe row is
/// re-keyed and shuffled before it can meet the build side — A5's Q6J
/// path).
pub fn shuffle_join_cost_s(cfg: &FlintConfig, probe_bytes: u64, build_bytes: u64) -> f64 {
    let sim = &cfg.sim;
    let conc = sim.max_concurrency.max(1) as f64;
    let split = cfg.flint.input_split_bytes.max(1) as f64;
    let stages = 2.0 * sim.scheduler_overhead_per_stage_s;
    let build_tasks = (build_bytes as f64 / split).ceil().max(1.0);
    let build_waves = (build_tasks / conc).ceil().max(1.0);
    let build_scan =
        build_waves * (sim.s3_first_byte_s + (build_bytes as f64).min(split) / (sim.s3_flint_mbps * 1e6));
    let probe_rows = probe_bytes as f64 / TRIP_ROW_BYTES;
    let shuffle_bytes = probe_rows * SHUFFLED_PAIR_BYTES;
    let probe_tasks = (probe_bytes as f64 / split).ceil().max(1.0);
    let writers = probe_tasks.min(conc).max(1.0);
    let readers = (cfg.flint.default_shuffle_partitions as f64).min(conc).max(1.0);
    let transfer =
        shuffle_bytes / (sim.sqs_mbps * 1e6) * (1.0 / writers + 1.0 / readers);
    stages + build_scan + transfer
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinStrategy {
    Broadcast,
    Shuffle,
}

impl JoinStrategy {
    pub fn name(self) -> &'static str {
        match self {
            JoinStrategy::Broadcast => "broadcast",
            JoinStrategy::Shuffle => "shuffle",
        }
    }
}

/// Pick the join strategy for a build side of `build_bytes` against a
/// probe side of `probe_bytes`. Returns the choice plus both estimated
/// extra costs. `flint.sql.broadcast_threshold_bytes` is an
/// eligibility cap: a build side larger than it never broadcasts, and
/// a threshold of 0 forces every join through the shuffle.
pub fn choose_join_strategy(
    cfg: &FlintConfig,
    probe_bytes: u64,
    build_bytes: u64,
) -> (JoinStrategy, f64, f64) {
    let b = broadcast_join_cost_s(cfg, probe_bytes, build_bytes);
    let s = shuffle_join_cost_s(cfg, probe_bytes, build_bytes);
    let eligible = build_bytes <= cfg.flint.sql.broadcast_threshold_bytes;
    let strategy = if eligible && b <= s { JoinStrategy::Broadcast } else { JoinStrategy::Shuffle };
    (strategy, b, s)
}

#[derive(Debug, Clone)]
pub struct JoinChoice {
    pub strategy: JoinStrategy,
    pub build: Table,
    pub probe: Table,
    pub build_bytes: u64,
    pub probe_bytes: u64,
    pub broadcast_cost_s: f64,
    pub shuffle_cost_s: f64,
    /// Shuffle-join partition count (unused by a broadcast join).
    pub partitions: usize,
    /// Human-readable rationale, rendered in EXPLAIN.
    pub reason: String,
}

#[derive(Debug, Clone)]
pub struct PhysicalChoice {
    pub optimizer: bool,
    pub join: Option<JoinChoice>,
    /// Aggregation shuffle width, when the plan aggregates.
    pub agg_partitions: Option<usize>,
}

impl PhysicalChoice {
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(j) = &self.join {
            out.push_str(&format!(
                "join: {} build={} ({} B) probe={} ({} B) cost[broadcast]={:.3}s cost[shuffle]={:.3}s partitions={} — {}\n",
                j.strategy.name(),
                j.build.name(),
                j.build_bytes,
                j.probe.name(),
                j.probe_bytes,
                j.broadcast_cost_s,
                j.shuffle_cost_s,
                j.partitions,
                j.reason,
            ));
        }
        if let Some(p) = self.agg_partitions {
            out.push_str(&format!("aggregate: partitions={p}\n"));
        }
        if !self.optimizer {
            out.push_str("(optimizer off: no pushdown, shuffle join, default partitions)\n");
        }
        out
    }
}

/// Total bytes a source scan will read, from the session's split
/// resolution (manifest-backed sources included).
fn source_bytes(sc: &FlintContext, table: Table) -> u64 {
    sc.input_splits(table.bucket(), table.prefix())
        .iter()
        .map(|s| s.end - s.start)
        .sum()
}

/// Stats-derived NDV bounds `(day, month)` for the plan's trips scan:
/// the day/month spans its input splits actually cover (per-object
/// manifest or HEAD-recovered stats — one stat-less split voids the
/// bound), with the day span further narrowed by any pushed day-range
/// predicate. These tighten the schema-wide `day`/`month` domains, and
/// with them the exchange partition counts picked below: a one-month
/// scan that groups by day needs ~31 partitions, not 2738.
fn trips_stat_bounds(sc: &FlintContext, p: &LogicalPlan) -> (Option<u64>, Option<u64>) {
    let scan = if p.fact.table == Table::Trips {
        &p.fact
    } else {
        match p.join.as_ref().filter(|j| j.dim.table == Table::Trips) {
            Some(j) => &j.dim,
            None => return (None, None),
        }
    };
    let splits = sc.input_splits(scan.table.bucket(), scan.table.prefix());
    if splits.is_empty() {
        return (None, None);
    }
    let mut days: Option<(i32, i32)> = None;
    let mut months: Option<(i32, i32)> = None;
    for s in &splits {
        let Some(st) = &s.stats else { return (None, None) };
        days = Some(days.map_or((st.min_day, st.max_day), |(lo, hi)| {
            (lo.min(st.min_day), hi.max(st.max_day))
        }));
        months = Some(months.map_or((st.min_month, st.max_month), |(lo, hi)| {
            (lo.min(st.min_month), hi.max(st.max_month))
        }));
    }
    let (mut dlo, mut dhi) = days.expect("non-empty splits");
    for pred in &scan.pushed {
        if let PushedPred::DayRange { lo, hi } = pred {
            dlo = dlo.max(*lo);
            dhi = dhi.min(*hi);
        }
    }
    // A disjoint predicate leaves zero groups; one partition still
    // carries the (empty) exchange.
    let span = |lo: i32, hi: i32| if hi < lo { 1 } else { (hi - lo) as u64 + 1 };
    let (mlo, mhi) = months.expect("non-empty splits");
    (Some(span(dlo, dhi)), Some(span(mlo, mhi)))
}

/// Make the physical decisions for an (optimized) logical plan,
/// possibly swapping the join sides so the smaller table builds.
/// Returns the final plan and the recorded choices.
pub fn plan_physical(sc: &FlintContext, plan: &LogicalPlan, optimizer: bool) -> (LogicalPlan, PhysicalChoice) {
    let cfg = sc.env().config();
    let mut p = plan.clone();
    let default_parts = cfg.flint.default_shuffle_partitions.max(1);
    // NDV-from-stats: tighten day/month domains to what the trips scan's
    // splits can actually produce (the swap below never moves the trips
    // table out of the plan, so computing the bounds up front is safe).
    let (day_ndv, month_ndv) =
        if optimizer { trips_stat_bounds(sc, &p) } else { (None, None) };
    let refine = move |c: Column| match c {
        Column::Day => day_ndv,
        Column::Month => month_ndv,
        _ => None,
    };

    let join = if p.join.is_some() {
        let fact_bytes = source_bytes(sc, p.fact.table);
        let dim_bytes = source_bytes(sc, p.join.as_ref().expect("join").dim.table);
        if optimizer && fact_bytes < dim_bytes {
            // Reorder: build from the smaller side. Swapping scan and
            // key keeps the (symmetric) inner equi-join's semantics.
            let j = p.join.as_mut().expect("join");
            std::mem::swap(&mut p.fact, &mut j.dim);
            std::mem::swap(&mut j.fact_key, &mut j.dim_key);
        }
        let j = p.join.as_ref().expect("join");
        let (probe_bytes, build_bytes) =
            if optimizer && fact_bytes < dim_bytes { (dim_bytes, fact_bytes) } else { (fact_bytes, dim_bytes) };
        let key_ndv = j.fact_key.ndv_refined(&refine).min(j.dim_key.ndv_refined(&refine));
        let partitions = key_ndv.min(default_parts as u64).max(1) as usize;
        let choice = if optimizer {
            let (strategy, b, s) = choose_join_strategy(cfg, probe_bytes, build_bytes);
            let reason = if build_bytes > cfg.flint.sql.broadcast_threshold_bytes {
                format!(
                    "build side exceeds flint.sql.broadcast_threshold_bytes={}",
                    cfg.flint.sql.broadcast_threshold_bytes
                )
            } else if strategy == JoinStrategy::Broadcast {
                "broadcast estimated cheaper".to_string()
            } else {
                "shuffle estimated cheaper".to_string()
            };
            JoinChoice {
                strategy,
                build: j.dim.table,
                probe: p.fact.table,
                build_bytes,
                probe_bytes,
                broadcast_cost_s: b,
                shuffle_cost_s: s,
                partitions,
                reason,
            }
        } else {
            let (_, b, s) = choose_join_strategy(cfg, probe_bytes, build_bytes);
            JoinChoice {
                strategy: JoinStrategy::Shuffle,
                build: j.dim.table,
                probe: p.fact.table,
                build_bytes,
                probe_bytes,
                broadcast_cost_s: b,
                shuffle_cost_s: s,
                partitions: default_parts,
                reason: "optimizer off".to_string(),
            }
        };
        Some(choice)
    } else {
        None
    };

    let agg_partitions = match &p.mode {
        Mode::Project { .. } => None,
        Mode::Aggregate { keys, .. } => {
            if optimizer {
                let mut groups: u64 = 1;
                for k in keys {
                    groups = groups.saturating_mul(k.ndv_refined(&refine));
                }
                Some(groups.min(default_parts as u64).max(1) as usize)
            } else {
                Some(default_parts)
            }
        }
    };

    (p, PhysicalChoice { optimizer, join, agg_partitions })
}

// ---------------------------------------------------------------------
// Lowering onto the Rdd lineage API
// ---------------------------------------------------------------------

/// One scan's lineage: source, pushed predicate ops in source order
/// (typed `DayRange`s stay visible to split pruning; opaque conjuncts
/// become raw-line `Filter`s), then the projection-aware parse.
fn scan_lineage(sc: &FlintContext, scan: &TableScan) -> Rdd {
    let mut rdd = sc.text_file(scan.table.bucket(), scan.table.prefix());
    let table = scan.table;
    for pred in &scan.pushed {
        match pred {
            PushedPred::DayRange { lo, hi } => rdd = rdd.filter_day_range(*lo, *hi),
            PushedPred::Generic(s) => {
                let s = s.clone();
                let cols: Vec<Column> = s.columns().into_iter().collect();
                rdd = rdd.filter(move |v| {
                    let Some(line) = v.as_str() else { return false };
                    let Some(cells) = parse_row(table, line, &cols) else { return false };
                    s.test(&col_accessor(&cols, &cells))
                });
            }
        }
    }
    let layout = scan.columns();
    rdd.flat_map(move |v| {
        let Some(line) = v.as_str() else { return Vec::new() };
        match parse_row(table, line, &layout) {
            Some(cells) => vec![Value::List(cells)],
            None => Vec::new(),
        }
    })
}

/// Read and filter the build table at the driver, keyed for the probe
/// side's map closure (the "broadcast variable").
fn broadcast_build(
    sc: &FlintContext,
    scan: &TableScan,
    key: &Scalar,
    int_key: bool,
) -> Result<HashMap<u64, Vec<Vec<Value>>>, SqlError> {
    let env = sc.env();
    let layout = scan.columns();
    let mut map: HashMap<u64, Vec<Vec<Value>>> = HashMap::new();
    let listed = env
        .s3()
        .list(scan.table.bucket(), scan.table.prefix())
        .map_err(|e| SqlError::new(format!("broadcast build of `{}`: {e}", scan.table.name()), 0))?;
    for (obj_key, _) in listed {
        let (obj, _dt) = env
            .s3()
            .get_object(scan.table.bucket(), &obj_key, env.flint_read_profile())
            .map_err(|e| {
                SqlError::new(format!("broadcast build of `{}`: {e}", scan.table.name()), 0)
            })?;
        let text = String::from_utf8_lossy(obj.bytes());
        'line: for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            for pred in &scan.pushed {
                let keep = match pred {
                    PushedPred::DayRange { lo, hi } => line_in_day_range(line, *lo, *hi),
                    PushedPred::Generic(s) => {
                        let cols: Vec<Column> = s.columns().into_iter().collect();
                        match parse_row(scan.table, line, &cols) {
                            Some(cells) => s.test(&col_accessor(&cols, &cells)),
                            None => false,
                        }
                    }
                };
                if !keep {
                    continue 'line;
                }
            }
            let Some(cells) = parse_row(scan.table, line, &layout) else { continue };
            let k = key.eval(&col_accessor(&layout, &cells));
            map.entry(encode_key(int_key, k)).or_default().push(cells);
        }
    }
    Ok(map)
}

fn encode_key(int_key: bool, v: f64) -> u64 {
    if int_key {
        (v as i64) as u64
    } else {
        norm(v).to_bits()
    }
}

/// Lower the final logical plan (post-physical-decisions) to lineage.
pub fn build_rdd(
    sc: &FlintContext,
    p: &LogicalPlan,
    choice: &PhysicalChoice,
) -> Result<Rdd, SqlError> {
    let fact_layout = p.fact.columns();
    let mut layout = fact_layout.clone();
    let mut rdd = scan_lineage(sc, &p.fact);

    if let Some(j) = &p.join {
        let jc = choice.join.as_ref().expect("join choice");
        let int_key = j.fact_key.is_int() && j.dim_key.is_int();
        let dim_layout = j.dim.columns();
        layout.extend(dim_layout.iter().copied());
        match jc.strategy {
            JoinStrategy::Broadcast => {
                let map = Arc::new(broadcast_build(sc, &j.dim, &j.dim_key, int_key)?);
                let fkey = j.fact_key.clone();
                let flayout = fact_layout.clone();
                rdd = rdd.flat_map(move |v| {
                    let Value::List(cells) = v else { return Vec::new() };
                    let k = fkey.eval(&col_accessor(&flayout, &cells));
                    match map.get(&encode_key(int_key, k)) {
                        None => Vec::new(),
                        Some(rows) => rows
                            .iter()
                            .map(|dim_cells| {
                                let mut merged = cells.clone();
                                merged.extend(dim_cells.iter().cloned());
                                Value::List(merged)
                            })
                            .collect(),
                    }
                });
            }
            JoinStrategy::Shuffle => {
                let fkey = j.fact_key.clone();
                let flayout = fact_layout.clone();
                let fact_pairs = rdd.flat_map(move |v| {
                    let Value::List(cells) = v else { return Vec::new() };
                    let k = key_value(int_key, fkey.eval(&col_accessor(&flayout, &cells)));
                    vec![Value::pair(k, Value::List(cells))]
                });
                let dkey = j.dim_key.clone();
                let dlayout = dim_layout.clone();
                let dim_pairs = scan_lineage(sc, &j.dim).flat_map(move |v| {
                    let Value::List(cells) = v else { return Vec::new() };
                    let k = key_value(int_key, dkey.eval(&col_accessor(&dlayout, &cells)));
                    vec![Value::pair(k, Value::List(cells))]
                });
                rdd = fact_pairs.join(&dim_pairs, jc.partitions).flat_map(|v| {
                    let Value::Pair(_, lr) = v else { return Vec::new() };
                    let Value::Pair(l, r) = *lr else { return Vec::new() };
                    let (Value::List(mut lc), Value::List(rc)) = (*l, *r) else {
                        return Vec::new();
                    };
                    lc.extend(rc);
                    vec![Value::List(lc)]
                });
            }
        }
    }

    // Residual (cross-table or un-pushed) conjuncts above the join.
    for pred in &p.filter {
        let s = pred.clone();
        let lay = layout.clone();
        rdd = rdd.filter(move |v| {
            let Value::List(cells) = v else { return false };
            s.test(&col_accessor(&lay, &cells))
        });
    }

    match &p.mode {
        Mode::Project { exprs } => {
            let exprs = exprs.clone();
            let ints = p.int_outputs.clone();
            let lay = layout.clone();
            rdd = rdd.flat_map(move |v| {
                let Value::List(cells) = v else { return Vec::new() };
                let acc = col_accessor(&lay, &cells);
                let row = exprs
                    .iter()
                    .zip(&ints)
                    .map(|(e, int)| out_value(*int, e.eval(&acc)))
                    .collect();
                vec![Value::List(row)]
            });
        }
        Mode::Aggregate { keys, aggs, select } => {
            let partitions = choice.agg_partitions.expect("aggregate partitions");
            let n_keys = keys.len();
            // Map side: (group key, per-aggregate state slots).
            let keys_cl = keys.clone();
            let key_ints: Vec<bool> = keys.iter().map(Scalar::is_int).collect();
            let aggs_cl = aggs.clone();
            let lay = layout.clone();
            rdd = rdd.flat_map(move |v| {
                let Value::List(cells) = v else { return Vec::new() };
                let acc = col_accessor(&lay, &cells);
                let key = match keys_cl.len() {
                    0 => Value::I64(0),
                    1 => key_value(key_ints[0], keys_cl[0].eval(&acc)),
                    _ => Value::List(
                        keys_cl
                            .iter()
                            .zip(&key_ints)
                            .map(|(k, int)| key_value(*int, k.eval(&acc)))
                            .collect(),
                    ),
                };
                let mut state = Vec::new();
                for a in &aggs_cl {
                    let arg = a.arg.as_ref().map(|e| e.eval(&acc)).unwrap_or(1.0);
                    match a.func {
                        AggFunc::Count => state.push(Value::I64(1)),
                        AggFunc::Sum | AggFunc::Min | AggFunc::Max => {
                            state.push(Value::F64(arg));
                        }
                        AggFunc::Avg => {
                            state.push(Value::F64(arg));
                            state.push(Value::I64(1));
                        }
                    }
                }
                vec![Value::pair(key, Value::List(state))]
            });
            // Combine: slot-wise fold (associative + commutative; sums
            // of integral values stay exact in f64 well past any
            // realistic row count, so fold order cannot change them).
            let ops = slot_ops(aggs);
            rdd = rdd.reduce_by_key(partitions, move |a, b| {
                let (Value::List(xa), Value::List(xb)) = (a, b) else { return Value::Null };
                let cells = xa
                    .into_iter()
                    .zip(xb)
                    .zip(&ops)
                    .map(|((x, y), op)| {
                        let (xf, yf) =
                            (x.as_f64().unwrap_or(f64::NAN), y.as_f64().unwrap_or(f64::NAN));
                        match op {
                            SlotOp::AddI => {
                                Value::I64(x.as_i64().unwrap_or(0) + y.as_i64().unwrap_or(0))
                            }
                            SlotOp::AddF => Value::F64(xf + yf),
                            SlotOp::MinF => Value::F64(xf.min(yf)),
                            SlotOp::MaxF => Value::F64(xf.max(yf)),
                        }
                    })
                    .collect();
                Value::List(cells)
            });
            // Finalize each group into `[key…, aggregate…]` f64 cells.
            let aggs_fin = aggs.clone();
            rdd = rdd.flat_map(move |v| {
                let Value::Pair(k, s) = v else { return Vec::new() };
                let Value::List(state) = *s else { return Vec::new() };
                let mut row: Vec<f64> = Vec::with_capacity(n_keys + aggs_fin.len());
                match (n_keys, *k) {
                    (0, _) => {}
                    (1, key) => row.push(key.as_f64().unwrap_or(f64::NAN)),
                    (_, Value::List(parts)) => {
                        row.extend(parts.iter().map(|p| p.as_f64().unwrap_or(f64::NAN)));
                    }
                    _ => return Vec::new(),
                }
                let mut i = 0;
                for a in &aggs_fin {
                    let slot = |j: usize| {
                        state.get(j).and_then(Value::as_f64).unwrap_or(f64::NAN)
                    };
                    match a.func {
                        AggFunc::Count | AggFunc::Sum | AggFunc::Min | AggFunc::Max => {
                            row.push(slot(i));
                            i += 1;
                        }
                        AggFunc::Avg => {
                            row.push(slot(i) / slot(i + 1));
                            i += 2;
                        }
                    }
                }
                vec![Value::List(row.into_iter().map(Value::F64).collect())]
            });
            // HAVING filters groups before the final projection.
            if let Some(h) = &p.having {
                let h = h.clone();
                rdd = rdd.filter(move |v| {
                    let Value::List(cells) = v else { return false };
                    let vals: Vec<f64> =
                        cells.iter().map(|c| c.as_f64().unwrap_or(f64::NAN)).collect();
                    h.eval(&vals[..n_keys.min(vals.len())], &vals[n_keys.min(vals.len())..])
                        != 0.0
                });
            }
            let select = select.clone();
            let ints = p.int_outputs.clone();
            rdd = rdd.flat_map(move |v| {
                let Value::List(cells) = v else { return Vec::new() };
                let vals: Vec<f64> =
                    cells.iter().map(|c| c.as_f64().unwrap_or(f64::NAN)).collect();
                let split = n_keys.min(vals.len());
                let (kv, av) = vals.split_at(split);
                let row = select
                    .iter()
                    .zip(&ints)
                    .map(|(e, int)| out_value(*int, e.eval(kv, av)))
                    .collect();
                vec![Value::List(row)]
            });
        }
    }
    Ok(rdd)
}

#[derive(Debug, Clone, Copy)]
enum SlotOp {
    AddI,
    AddF,
    MinF,
    MaxF,
}

fn slot_ops(aggs: &[Aggregate]) -> Vec<SlotOp> {
    let mut ops = Vec::new();
    for a in aggs {
        match a.func {
            AggFunc::Count => ops.push(SlotOp::AddI),
            AggFunc::Sum => ops.push(SlotOp::AddF),
            AggFunc::Min => ops.push(SlotOp::MinF),
            AggFunc::Max => ops.push(SlotOp::MaxF),
            AggFunc::Avg => {
                ops.push(SlotOp::AddF);
                ops.push(SlotOp::AddI);
            }
        }
    }
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trip_row_parses_projected_fields_only() {
        let line = "1,2013-01-08 10:15:00,2013-01-08 10:35:30,2,3.5,-74.0,40.7,-74.01,40.71,1,12.5,2.0,15.5";
        let cols = [Column::Hour, Column::Credit, Column::TipAmount];
        let row = parse_trip_row(line, &cols).unwrap();
        assert_eq!(row, vec![Value::I64(10), Value::I64(1), Value::F64(2.0)]);
        // A full-layout parse works too.
        let all = parse_trip_row(line, Table::Trips.columns()).unwrap();
        assert_eq!(all.len(), Table::Trips.columns().len());
        // Wrong column counts and garbage referenced fields drop.
        assert!(parse_trip_row("1,2,3", &cols).is_none());
        assert!(parse_trip_row(&format!("{line},extra"), &cols).is_none());
        let bad = line.replace("2013-01-08 10:35:30", "not-a-date");
        assert!(parse_trip_row(&bad, &cols).is_none());
        // …but garbage in an *unreferenced* field is fine.
        let bad_fare = line.replace(",12.5,", ",oops,");
        assert!(parse_trip_row(&bad_fare, &[Column::Hour]).is_some());
        assert!(parse_trip_row(&bad_fare, &[Column::FareAmount]).is_none());
    }

    #[test]
    fn weather_row_parses_and_buckets() {
        let row = parse_weather_row("17,0.300", Table::Weather.columns()).unwrap();
        assert_eq!(row[0], Value::I64(17));
        assert_eq!(row[1], Value::F64(0.3));
        assert_eq!(row[2], Value::I64(precip_bucket(0.3) as i64));
        // Inflated weather lines (padded fraction digits) still parse.
        assert!(parse_weather_row("17,0.3000000000", &[Column::Bucket]).is_some());
        assert!(parse_weather_row("not-a-line", &[Column::Bucket]).is_none());
    }

    #[test]
    fn cost_model_crosses_over_in_build_bytes() {
        // Production scale (64 MB splits, concurrency 80): the probe
        // side runs in one wave. Under `for_tests()`'s 64 KB splits the
        // same probe would take 1024 waves, each re-reading the build
        // table — there broadcast genuinely loses even at 30 KB, which
        // is the model working, not the property under test.
        let cfg = FlintConfig::default();
        let probe = 512 * 1024 * 1024;
        // Tiny build side: broadcast must win.
        let (s, b, sh) = choose_join_strategy(&cfg, probe, 30_000);
        assert_eq!(s, JoinStrategy::Broadcast, "b={b} sh={sh}");
        // Build cost grows linearly with build bytes; shuffle cost is
        // flat in build bytes (modulo its own tiny scan term), so a
        // large enough build side must flip the choice.
        let (s2, b2, sh2) = choose_join_strategy(&cfg, probe, 8 * 1024 * 1024 * 1024);
        assert_eq!(s2, JoinStrategy::Shuffle, "b={b2} sh={sh2}");
        assert!(b2 > b, "broadcast cost is increasing in build bytes");
        // The threshold is an eligibility cap: 0 forces shuffle even
        // when broadcast is estimated cheaper.
        let mut forced = cfg.clone();
        forced.flint.sql.broadcast_threshold_bytes = 0;
        let (s3, _, _) = choose_join_strategy(&forced, probe, 30_000);
        assert_eq!(s3, JoinStrategy::Shuffle);
    }
}
