//! Hand-rolled SQL lexer — no dependencies, byte offsets on every
//! token so errors anywhere downstream (parse, analysis, planning) can
//! point at the exact place in the query text.

use std::fmt;

/// A typed SQL front-end error. Every failure mode — lexing, parsing,
/// name resolution, planning — surfaces as one of these, carrying the
/// byte offset into the original query text where it was detected.
/// The fuzz suite pins the contract: arbitrary garbage in, `SqlError`
/// with an in-bounds offset out, never a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SqlError {
    pub message: String,
    /// Byte offset into the query text (<= text.len(); equal at EOF).
    pub offset: usize,
}

impl SqlError {
    pub fn new(message: impl Into<String>, offset: usize) -> SqlError {
        SqlError { message: message.into(), offset }
    }
}

impl fmt::Display for SqlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SQL error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for SqlError {}

/// Punctuation and operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sym {
    LParen,
    RParen,
    Comma,
    Dot,
    Star,
    Plus,
    Minus,
    Slash,
    Eq,
    NotEq,
    Lt,
    Le,
    Gt,
    Ge,
    Semi,
}

impl Sym {
    pub fn text(self) -> &'static str {
        match self {
            Sym::LParen => "(",
            Sym::RParen => ")",
            Sym::Comma => ",",
            Sym::Dot => ".",
            Sym::Star => "*",
            Sym::Plus => "+",
            Sym::Minus => "-",
            Sym::Slash => "/",
            Sym::Eq => "=",
            Sym::NotEq => "<>",
            Sym::Lt => "<",
            Sym::Le => "<=",
            Sym::Gt => ">",
            Sym::Ge => ">=",
            Sym::Semi => ";",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Bare identifier or keyword (keyword-ness is decided by the
    /// parser, case-insensitively — SQL has no reserved-word lexer
    /// state worth hand-rolling).
    Ident(String),
    Number(f64),
    Str(String),
    Sym(Sym),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub tok: Tok,
    /// Byte offset of the token's first character.
    pub offset: usize,
}

impl Token {
    /// Render for "found X" error messages.
    pub fn describe(&self) -> String {
        match &self.tok {
            Tok::Ident(s) => format!("`{s}`"),
            Tok::Number(n) => format!("number `{n}`"),
            Tok::Str(s) => format!("string '{s}'"),
            Tok::Sym(s) => format!("`{}`", s.text()),
        }
    }

    /// Case-insensitive keyword test.
    pub fn is_kw(&self, kw: &str) -> bool {
        matches!(&self.tok, Tok::Ident(s) if s.eq_ignore_ascii_case(kw))
    }
}

/// Tokenize `text`. Unknown characters, malformed numbers and
/// unterminated strings are `SqlError`s at the offending byte.
pub fn lex(text: &str) -> Result<Vec<Token>, SqlError> {
    let bytes = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i];
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        let start = i;
        if c == b'\'' {
            i += 1;
            let sstart = i;
            while i < bytes.len() && bytes[i] != b'\'' {
                i += 1;
            }
            if i >= bytes.len() {
                return Err(SqlError::new("unterminated string literal", start));
            }
            let s = text[sstart..i].to_string();
            i += 1;
            out.push(Token { tok: Tok::Str(s), offset: start });
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
                i += 1;
            }
            out.push(Token { tok: Tok::Ident(text[start..i].to_string()), offset: start });
            continue;
        }
        if c.is_ascii_digit() || (c == b'.' && bytes.get(i + 1).is_some_and(u8::is_ascii_digit)) {
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                i += 1;
            }
            if i < bytes.len() && bytes[i] == b'.' {
                i += 1;
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    i += 1;
                }
            }
            if i < bytes.len() && (bytes[i] == b'e' || bytes[i] == b'E') {
                let mut j = i + 1;
                if j < bytes.len() && (bytes[j] == b'+' || bytes[j] == b'-') {
                    j += 1;
                }
                if j < bytes.len() && bytes[j].is_ascii_digit() {
                    i = j;
                    while i < bytes.len() && bytes[i].is_ascii_digit() {
                        i += 1;
                    }
                }
            }
            let value: f64 = text[start..i]
                .parse()
                .map_err(|_| SqlError::new(format!("bad number `{}`", &text[start..i]), start))?;
            if !value.is_finite() {
                return Err(SqlError::new(format!("number `{}` overflows", &text[start..i]), start));
            }
            out.push(Token { tok: Tok::Number(value), offset: start });
            continue;
        }
        let sym = match c {
            b'(' => Sym::LParen,
            b')' => Sym::RParen,
            b',' => Sym::Comma,
            b'.' => Sym::Dot,
            b'*' => Sym::Star,
            b'+' => Sym::Plus,
            b'-' => Sym::Minus,
            b'/' => Sym::Slash,
            b';' => Sym::Semi,
            b'=' => Sym::Eq,
            b'!' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    i += 1;
                    Sym::NotEq
                } else {
                    return Err(SqlError::new("unexpected `!` (did you mean `!=`?)", start));
                }
            }
            b'<' => match bytes.get(i + 1) {
                Some(b'=') => {
                    i += 1;
                    Sym::Le
                }
                Some(b'>') => {
                    i += 1;
                    Sym::NotEq
                }
                _ => Sym::Lt,
            },
            b'>' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    i += 1;
                    Sym::Ge
                } else {
                    Sym::Gt
                }
            }
            other => {
                return Err(SqlError::new(
                    format!("unexpected character `{}`", char::from(other)),
                    start,
                ));
            }
        };
        i += 1;
        out.push(Token { tok: Tok::Sym(sym), offset: start });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_symbols_numbers_idents_strings() {
        let toks = lex("SELECT a.b, COUNT(*) FROM t WHERE x >= -74.5 AND y <> 'nyc';").unwrap();
        let kinds: Vec<&Tok> = toks.iter().map(|t| &t.tok).collect();
        assert!(matches!(kinds[0], Tok::Ident(s) if s == "SELECT"));
        assert!(kinds.contains(&&Tok::Sym(Sym::Dot)));
        assert!(kinds.contains(&&Tok::Sym(Sym::Star)));
        assert!(kinds.contains(&&Tok::Sym(Sym::Ge)));
        assert!(kinds.contains(&&Tok::Sym(Sym::NotEq)));
        assert!(kinds.contains(&&Tok::Number(74.5)));
        assert!(kinds.contains(&&Tok::Str("nyc".to_string())));
        // Offsets point at the token's first byte.
        assert_eq!(toks[0].offset, 0);
        assert_eq!(toks[1].offset, 7);
    }

    #[test]
    fn number_forms() {
        let toks = lex("1 2.5 .5 1e3 2E-2 7.").unwrap();
        let nums: Vec<f64> = toks
            .iter()
            .filter_map(|t| match t.tok {
                Tok::Number(n) => Some(n),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec![1.0, 2.5, 0.5, 1000.0, 0.02, 7.0]);
    }

    #[test]
    fn errors_carry_offsets() {
        let e = lex("SELECT @").unwrap_err();
        assert_eq!(e.offset, 7);
        let e = lex("SELECT 'oops").unwrap_err();
        assert_eq!(e.offset, 7);
        let e = lex("a ! b").unwrap_err();
        assert_eq!(e.offset, 2);
        let e = lex("SELECT 1e400").unwrap_err();
        assert_eq!(e.offset, 7);
    }
}
