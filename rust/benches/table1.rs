//! `cargo bench --bench table1` — regenerates the paper's Table I
//! (experiment T1 in DESIGN.md §6): query latency and estimated cost for
//! Q0–Q6 under Flint, PySpark, and Scala Spark, in measured mode plus
//! the analytic paper-scale extrapolation printed beside the published
//! numbers.
//!
//! Env knobs: `FLINT_BENCH_TRIPS` (default 1,000,000),
//! `FLINT_BENCH_TRIALS` (default 5).

use flint::bench::{run_table1, Table1Options};
use flint::config::FlintConfig;
use flint::util::human_bytes;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    // Splits sized so the measured run has multiple waves per stage.
    cfg.flint.input_split_bytes = 8 * 1024 * 1024;
    cfg.data.object_bytes = 16 * 1024 * 1024;

    let opts = Table1Options {
        trips: env_u64("FLINT_BENCH_TRIPS", 1_000_000),
        trials_flint: env_u64("FLINT_BENCH_TRIALS", 5) as usize,
        trials_cluster: 3,
        // Table I plus the Q6J shuffle-join extension (measured
        // cells only; no published row to extrapolate against).
        queries: flint::compute::queries::QueryId::ALL_WITH_JOINS.to_vec(),
        paper_scale: true,
    };

    eprintln!(
        "table1 bench: {} trips, {} flint trials (FLINT_BENCH_TRIPS / FLINT_BENCH_TRIALS to change)",
        opts.trips, opts.trials_flint
    );
    let t0 = std::time::Instant::now();
    let (ds, rows) = run_table1(&cfg, &opts).expect("table1 run");
    println!(
        "dataset: {} trips / {} in {} objects; harness wall time {:.1}s\n",
        ds.trips,
        human_bytes(ds.total_bytes),
        ds.num_objects(),
        t0.elapsed().as_secs_f64()
    );
    println!("{}", flint::bench::table1::render_measured(&rows));
    println!("{}", flint::bench::table1::render_paper_scale(&rows));

    // Diagnostics: where Flint time goes per query (the paper's
    // "dependent on the number of intermediate groups" explanation).
    println!("## Flint time breakdown (per-task sums, last trial)\n");
    for row in &rows {
        println!(
            "{}: {} | {} msgs, {} invocations, {} chains",
            row.query,
            row.flint_report.timeline,
            row.flint_report.shuffle_msgs,
            row.flint_report.invocations,
            row.flint_report.chains
        );
    }
}
