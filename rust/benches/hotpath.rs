//! `cargo bench --bench hotpath` — experiment P1 (DESIGN.md §6/§9): the
//! L3 hot-path microbenchmarks driving the performance pass. Reports
//! real wall-clock throughput of the executor inner loops: CSV parse →
//! columnar batch, native kernel, PJRT artifact dispatch, shuffle record
//! codec, and the makespan scheduler.

use flint::compute::batch::ColumnBatch;
use flint::compute::kernels::{prepare_keys, prepare_values, run_batch_native, HistAccum};
use flint::compute::queries::QueryId;
use flint::data::taxi::generate_csv_object;
use flint::config::ShuffleCodec;
use flint::exec::shuffle::{pack_kernel_run, ShuffleRec};
use flint::runtime::PjrtRuntime;
use flint::simtime::makespan;
use std::time::Instant;

fn time<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

fn main() {
    println!("## P1 — L3 hot-path throughput (real wall clock)\n");
    let rows: u64 = std::env::var("FLINT_BENCH_ROWS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_000_000);
    let csv = generate_csv_object(7, 0, rows);
    let mb = csv.len() as f64 / 1e6;
    println!("corpus: {rows} rows, {mb:.1} MB\n");
    println!("| path | throughput | detail |");
    println!("|---|---|---|");

    // 1. Line splitting only (Q0's loop).
    let (count, dt) = time(|| {
        let mut n = 0u64;
        for _ in flint::compute::csv::SplitLines::new(&csv, csv.len() as u64, true) {
            n += 1;
        }
        n
    });
    assert_eq!(count, rows);
    println!(
        "| line split (Q0) | {:.0} MB/s | {:.1} Mrows/s |",
        mb / dt,
        rows as f64 / dt / 1e6
    );

    // 2. Full parse into columnar batches.
    let spec = QueryId::Q1.spec();
    let capacity = 8192;
    let (parsed, dt) = time(|| {
        let mut batch = ColumnBatch::with_capacity(capacity);
        let mut total = 0u64;
        let mut acc = HistAccum::new(spec.buckets);
        for line in flint::compute::csv::SplitLines::new(&csv, csv.len() as u64, true) {
            if batch.push_line(line) {
                total += 1;
            }
            if batch.is_full() {
                let keys = prepare_keys(&spec, &batch, None);
                let values = prepare_values(&spec, &batch);
                run_batch_native(&spec, &batch, &keys, &values, &mut acc);
                batch.clear();
            }
        }
        if !batch.is_empty() {
            let keys = prepare_keys(&spec, &batch, None);
            let values = prepare_values(&spec, &batch);
            run_batch_native(&spec, &batch, &keys, &values, &mut acc);
        }
        (total, acc)
    });
    assert_eq!(parsed.0, rows);
    println!(
        "| parse + native Q1 kernel | {:.0} MB/s | {:.2} Mrows/s |",
        mb / dt,
        rows as f64 / dt / 1e6
    );

    // 3. PJRT artifact dispatch (when artifacts are built).
    if PjrtRuntime::available("artifacts") {
        let rt = PjrtRuntime::open("artifacts").expect("artifacts");
        rt.warmup().expect("warmup");
        let b = rt.batch_rows();
        let mut batch = ColumnBatch::with_capacity(b);
        for line in flint::compute::csv::SplitLines::new(&csv, csv.len() as u64, true) {
            if batch.is_full() {
                break;
            }
            batch.push_line(line);
        }
        batch.pad_to_capacity();
        let keys = prepare_keys(&spec, &batch, None);
        let values = prepare_values(&spec, &batch);
        let iters = 200;
        let (_, dt) = time(|| {
            let mut acc = HistAccum::new(spec.buckets);
            for _ in 0..iters {
                rt.run_hist(&spec, &batch, &keys, &values, &mut acc).expect("pjrt");
            }
        });
        let rps = (iters * b) as f64 / dt;
        println!(
            "| PJRT q1_hist dispatch | {:.2} Mrows/s | {:.0} µs/batch of {b} |",
            rps / 1e6,
            dt / iters as f64 * 1e6
        );
    } else {
        println!("| PJRT q1_hist dispatch | (skipped) | run `make artifacts` first |");
    }

    // 4. Shuffle record codec.
    let recs: Vec<ShuffleRec> = (0..100_000)
        .map(|i| ShuffleRec::Kernel { key: i % 180, sum: i as f64, count: 1.0 })
        .collect();
    let (buf, enc_dt) = time(|| {
        let mut buf = Vec::new();
        for r in &recs {
            r.encode_into(&mut buf);
        }
        buf
    });
    let (decoded, dec_dt) = time(|| ShuffleRec::decode_all(&buf).expect("decode"));
    assert_eq!(decoded.len(), recs.len());
    println!(
        "| shuffle codec | enc {:.1} / dec {:.1} Mrec/s | {} bytes |",
        recs.len() as f64 / enc_dt / 1e6,
        recs.len() as f64 / dec_dt / 1e6,
        buf.len()
    );

    // 4b. Wire codec byte ratio: one partition's sorted run of kernel
    // partials packed under both codecs — the quantity the A6 ablation
    // measures per shuffle edge.
    let run: Vec<(i64, f64, f64)> = (0..100_000i64)
        .map(|i| (i / 556, (i % 97) as f64, 1.0))
        .collect(); // sorted keys, ~556 partials per key: a mapper's emit order
    let mut sizes = [0usize; 2];
    for (i, codec) in [ShuffleCodec::Rows, ShuffleCodec::Columnar].into_iter().enumerate() {
        let (buf, dt) = time(|| {
            let mut buf = Vec::new();
            for rec in pack_kernel_run(&run, codec) {
                rec.encode_into(&mut buf);
            }
            buf
        });
        let decoded = ShuffleRec::decode_all(&buf).expect("decode");
        let logical: usize = decoded
            .iter()
            .map(|r| match r {
                ShuffleRec::Chunk { keys, .. } => keys.len(),
                _ => 1,
            })
            .sum();
        assert_eq!(logical, run.len());
        sizes[i] = buf.len();
        println!(
            "| pack+encode {codec:?} | {:.1} Mrec/s | {} bytes |",
            run.len() as f64 / dt / 1e6,
            buf.len()
        );
    }
    assert!(
        sizes[1] < sizes[0],
        "columnar chunks must shrink the wire: {} vs {} bytes",
        sizes[1],
        sizes[0]
    );
    println!(
        "| chunk codec byte ratio | columnar/rows = {:.3} | {} vs {} bytes |",
        sizes[1] as f64 / sizes[0] as f64,
        sizes[1],
        sizes[0]
    );

    // 5. Makespan scheduler at paper scale.
    let durations: Vec<f64> = (0..3440).map(|i| 2.0 + (i % 7) as f64 * 0.1).collect();
    let iters = 1000;
    let (_, dt) = time(|| {
        for _ in 0..iters {
            std::hint::black_box(makespan(&durations, 80));
        }
    });
    println!(
        "| makespan (3440 tasks, 80 slots) | {:.0} µs/call | {iters} iters |",
        dt / iters as f64 * 1e6
    );

    // 5b. Large-k guard: above `HEAP_SLOT_THRESHOLD` the earliest-slot
    // selection must run on the binary heap (O(n log k)), not the
    // linear scan (O(n·k)). At k=20,000 an O(n·k) scan would cost
    // ~300x the k=64 call on the same task list; assert we stay within
    // a 25x envelope (plus absolute slack for timer noise).
    let n = 200_000usize;
    let durations: Vec<f64> = (0..n).map(|i| 0.5 + (i % 13) as f64 * 0.05).collect();
    let (small, dt_small) = time(|| std::hint::black_box(makespan(&durations, 64)));
    let (big, dt_big) = time(|| std::hint::black_box(makespan(&durations, 20_000)));
    assert!(small > 0.0 && big > 0.0);
    println!(
        "| makespan heap path (200k tasks, 20k slots) | {:.1} ms/call | linear k=64: {:.1} ms |",
        dt_big * 1e3,
        dt_small * 1e3
    );
    assert!(
        dt_big < dt_small * 25.0 + 0.05,
        "large-k makespan regressed to O(n*k): k=20000 took {dt_big:.3}s vs k=64 {dt_small:.3}s"
    );
}
