//! `cargo bench --bench shuffle_ablation [-- --smoke]` — experiment A1
//! (DESIGN.md §6): the §VI future-work comparison between Flint's SQS
//! shuffle and Qubole's S3 shuffle, swept over query group counts — each
//! backend under both the serial barrier clock and the pipelined DAG
//! scheduler (both latencies come from the same execution, so the pair
//! is exact). Also sweeps the A6 wire-codec byte ratio (rows vs
//! columnar chunks) and the A7 stats-based scan-pruning GET counts,
//! plus the A9 SQL-optimizer ablation (every Table I query compiled
//! from SQL with `flint.sql.optimizer` on vs off, and the cost-based
//! join planner checked against the measured A5 crossover) and the A10
//! scale-out exchange sweep (the direct S3 exchange's O(P·R) object
//! count vs the multi-level tree's O((P+R)·√n), plus the per-edge
//! `flint.shuffle.backend = auto` selection) and the A11 lineage-cache
//! ablation (cold build vs warm cached re-run on a Table I-style
//! aggregation and a Q6J-style join, plus the capacity-0 off switch);
//! `--smoke` mode (CI) runs a small dataset and exits non-zero if the
//! columnar codec fails to shrink any shuffling Table I query or Q6J,
//! if pruning stops skipping GETs, if optimizer-on ever loses to
//! optimizer-off on any SQL query, if the planner's
//! broadcast-vs-shuffle pick disagrees with the measured winner, if
//! the tree exchange stops beating direct on total S3 requests at a
//! ≥1024-way fan-out, if the auto backend ever loses to the better
//! fixed backend, if a warm cached re-run fails to beat its cold build
//! run on BOTH latency and GB-seconds, or if the capacity-0 cache
//! stops being byte-identical to a marker-free baseline — so a codec,
//! pruning, optimizer, exchange, or cache regression fails PRs instead
//! of waiting for a nightly bench run. The A11 rows are also dropped
//! as `BENCH_cache.json` for the roadmap's numbers.

use flint::bench::micro::{
    backend_auto_ablation, cache_ablation, cache_off_identity, codec_byte_ratio, exchange_sweep,
    join_crossover, pruning_ablation, shuffle_ablation, sql_cbo_agreement,
    sql_optimizer_ablation,
};
use flint::compute::queries::QueryId;
use flint::config::FlintConfig;
use flint::util::json::Json;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    if smoke {
        // CI-sized: small objects/splits, PJRT off (no artifacts in CI
        // runners).
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 512 * 1024;
        cfg.flint.use_pjrt = false;
        cfg.sim.max_concurrency = 8;
    } else {
        cfg.data.object_bytes = 8 * 1024 * 1024;
        cfg.flint.input_split_bytes = 8 * 1024 * 1024;
    }

    let trips = std::env::var("FLINT_BENCH_TRIPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 20_000 } else { 400_000 });
    let mut failed = false;

    // A6 — wire codec byte ratio: every Table I query plus Q6J, rows vs
    // columnar chunks. Both runs are oracle-checked inside the harness.
    println!("## A6 — shuffle wire codec: rows vs columnar chunks\n");
    println!("| query | rows codec (B) | columnar (B) | ratio |");
    println!("|---|---|---|---|");
    let codec_rows =
        codec_byte_ratio(&cfg, trips, &QueryId::ALL_WITH_JOINS).expect("codec bench");
    let mut codec_json = Vec::new();
    for (q, rows_b, col_b) in &codec_rows {
        let ratio = if *rows_b > 0 { *col_b as f64 / *rows_b as f64 } else { 0.0 };
        println!("| {q} | {rows_b} | {col_b} | {ratio:.2} |");
        if *rows_b > 0 && col_b >= rows_b {
            eprintln!("REGRESSION: {q} columnar shuffle {col_b} B did not beat rows {rows_b} B");
            failed = true;
        }
        codec_json.push(
            Json::obj()
                .set("query", q.name())
                .set("rows_bytes", *rows_b)
                .set("columnar_bytes", *col_b),
        );
    }

    // A7 — stats-based scan pruning: a day-windowed Q1, prune on vs off.
    let (pruned_gets, unpruned_gets, skipped) =
        pruning_ablation(&cfg, trips, 0, 200).expect("pruning bench");
    println!("\n## A7 — stats-based scan pruning (Q1, day window [0, 200])\n");
    println!(
        "S3 GETs: {pruned_gets} pruned vs {unpruned_gets} unpruned ({skipped} splits skipped)"
    );
    if skipped == 0 || pruned_gets >= unpruned_gets {
        eprintln!(
            "REGRESSION: pruning skipped {skipped} splits, {pruned_gets} vs {unpruned_gets} GETs"
        );
        failed = true;
    }
    // A9 — SQL optimizer ablation: every Table I query from its SQL
    // text, `flint.sql.optimizer` on vs off (oracle-checked inside the
    // harness; identical answers enforced there too).
    println!("\n## A9 — SQL optimizer ablation (Table I queries from SQL)\n");
    println!("| query | join pick | opt on (s) | opt off (s) | on $ | off $ |");
    println!("|---|---|---|---|---|---|");
    let sql_rows = sql_optimizer_ablation(&cfg, trips).expect("sql ablation");
    let mut sql_json = Vec::new();
    for r in &sql_rows {
        println!(
            "| {} | {} | {:.2} | {:.2} | {:.4} | {:.4} |",
            r.query,
            r.join_strategy.unwrap_or("-"),
            r.on_latency_s,
            r.off_latency_s,
            r.on_usd,
            r.off_usd
        );
        if r.on_latency_s > r.off_latency_s * 1.02 + 1e-6 {
            eprintln!(
                "REGRESSION: {} optimizer-on {:.3}s lost to optimizer-off {:.3}s",
                r.query, r.on_latency_s, r.off_latency_s
            );
            failed = true;
        }
        sql_json.push(
            Json::obj()
                .set("query", r.query.name())
                .set("join", r.join_strategy.unwrap_or("-"))
                .set("on_latency_s", r.on_latency_s)
                .set("off_latency_s", r.off_latency_s)
                .set("on_usd", r.on_usd)
                .set("off_usd", r.off_usd),
        );
    }

    // A9 agreement check: the cost model's broadcast-vs-shuffle pick vs
    // the measured A5 winner, one dimension size on each side of the
    // crossover.
    let agree_trips = trips.min(50_000);
    let agreement =
        sql_cbo_agreement(&cfg, agree_trips, &[0, 64 * 1024 * 1024]).expect("cbo agreement");
    println!("\ncost-model agreement with the measured A5 winner:");
    for (dim_bytes, measured, planned) in &agreement {
        println!(
            "  dim {dim_bytes:>10} B: measured {} / planned {}",
            measured.name(),
            planned.name()
        );
        if measured != planned {
            eprintln!(
                "REGRESSION: at {dim_bytes} B dim the planner picked {} but {} won",
                planned.name(),
                measured.name()
            );
            failed = true;
        }
    }

    // A10 — exchange sweep: direct vs tree S3 exchange on a synthetic
    // P-producer × R-partition edge (the tree forced on at every point,
    // so both sides of the crossover are measured; record streams are
    // checked identical inside the harness). At ≥1024-way fan-outs the
    // merge level must pay for itself in total S3 requests.
    println!("\n## A10 — direct vs tree S3 exchange (request totals per topology)\n");
    println!("| producers x partitions | direct reqs | tree reqs | direct wall (s) | tree wall (s) |");
    println!("|---|---|---|---|---|");
    let sweep_points: &[(u32, u32)] = if smoke {
        &[(8, 8), (32, 1024)]
    } else {
        &[(8, 8), (16, 64), (32, 256), (32, 1024), (64, 2048)]
    };
    let exchange_rows = exchange_sweep(&cfg, sweep_points).expect("exchange sweep");
    let mut exchange_json = Vec::new();
    for r in &exchange_rows {
        println!(
            "| {}x{} | {} | {} | {:.3} | {:.3} |",
            r.producers,
            r.partitions,
            r.direct_requests,
            r.tree_requests,
            r.direct_wall_s,
            r.tree_wall_s
        );
        if r.producers.max(r.partitions) >= 1024 && r.tree_requests >= r.direct_requests {
            eprintln!(
                "REGRESSION: {}x{} tree exchange made {} S3 requests vs direct's {}",
                r.producers, r.partitions, r.tree_requests, r.direct_requests
            );
            failed = true;
        }
        exchange_json.push(
            Json::obj()
                .set("producers", r.producers as u64)
                .set("partitions", r.partitions as u64)
                .set("direct_requests", r.direct_requests)
                .set("tree_requests", r.tree_requests)
                .set("direct_wall_s", r.direct_wall_s)
                .set("tree_wall_s", r.tree_wall_s),
        );
    }

    // A10 — backend auto-selection: `auto` must never lose to the
    // better fixed backend (same tolerance as the A9 optimizer gate).
    println!("\n## A10 — per-edge backend auto-selection (latency per backend)\n");
    println!("| query | sqs (s) | s3 (s) | auto (s) |");
    println!("|---|---|---|---|");
    let auto_rows =
        backend_auto_ablation(&cfg, trips.min(100_000), &[QueryId::Q1, QueryId::Q6J])
            .expect("auto ablation");
    let mut auto_json = Vec::new();
    for (q, sqs_s, s3_s, auto_s) in &auto_rows {
        println!("| {q} | {sqs_s:.3} | {s3_s:.3} | {auto_s:.3} |");
        if *auto_s > sqs_s.min(*s3_s) * 1.02 + 1e-6 {
            eprintln!(
                "REGRESSION: {q} auto backend {auto_s:.3}s lost to sqs {sqs_s:.3}s / s3 {s3_s:.3}s"
            );
            failed = true;
        }
        auto_json.push(
            Json::obj()
                .set("query", q.name())
                .set("sqs_s", *sqs_s)
                .set("s3_s", *s3_s)
                .set("auto_s", *auto_s),
        );
    }

    // A11 — lineage cache: the same handles run twice, cold build vs
    // warm cached re-run. The warm row must win on BOTH axes — latency
    // (a truncated plan skips the scan) and GB-seconds (the skipped
    // work is also unbilled) — and the capacity-0 off switch must stay
    // byte-identical to a marker-free baseline (checked inside the
    // harness with modeled clocks).
    println!("\n## A11 — lineage cache: cold build vs warm cached re-run\n");
    println!("| workload | cold (s) | warm (s) | cold GB-s | warm GB-s | cold $ | warm $ | builds | hits |");
    println!("|---|---|---|---|---|---|---|---|---|");
    let cache_rows = cache_ablation(&cfg, trips.min(100_000)).expect("cache ablation");
    let mut cache_json = Vec::new();
    for r in &cache_rows {
        println!(
            "| {} | {:.3} | {:.3} | {:.4} | {:.4} | {:.5} | {:.5} | {} | {} |",
            r.name, r.cold_s, r.warm_s, r.cold_gb_s, r.warm_gb_s, r.cold_usd, r.warm_usd,
            r.builds, r.hits
        );
        if r.warm_s >= r.cold_s {
            eprintln!(
                "REGRESSION: {} warm re-run {:.3}s did not beat cold {:.3}s",
                r.name, r.warm_s, r.cold_s
            );
            failed = true;
        }
        if r.warm_gb_s >= r.cold_gb_s {
            eprintln!(
                "REGRESSION: {} warm re-run {:.4} GB-s did not beat cold {:.4} GB-s",
                r.name, r.warm_gb_s, r.cold_gb_s
            );
            failed = true;
        }
        cache_json.push(
            Json::obj()
                .set("workload", r.name)
                .set("cold_s", r.cold_s)
                .set("warm_s", r.warm_s)
                .set("cold_gb_s", r.cold_gb_s)
                .set("warm_gb_s", r.warm_gb_s)
                .set("cold_usd", r.cold_usd)
                .set("warm_usd", r.warm_usd)
                .set("builds", r.builds)
                .set("hits", r.hits),
        );
    }
    if let Err(e) = cache_off_identity(&cfg, trips.min(20_000)) {
        eprintln!("REGRESSION: cache off-switch identity broke: {e:#}");
        failed = true;
    } else {
        println!("\n(capacity-0 off switch: marker-laden report byte-identical to marker-free)");
    }
    let cache_blob = Json::obj()
        .set("bench", "cache_ablation")
        .set("trips", trips.min(100_000))
        .set("rows", Json::Arr(cache_json.clone()))
        .encode();
    if let Err(e) = std::fs::write("BENCH_cache.json", format!("{cache_blob}\n")) {
        eprintln!("warning: could not write BENCH_cache.json: {e}");
    }

    println!(
        "\n{}",
        Json::obj()
            .set("bench", "codec_and_pruning")
            .set("trips", trips)
            .set("codec", Json::Arr(codec_json))
            .set("pruned_gets", pruned_gets)
            .set("unpruned_gets", unpruned_gets)
            .set("splits_pruned", skipped)
            .set("sql_optimizer", Json::Arr(sql_json))
            .set("exchange_sweep", Json::Arr(exchange_json))
            .set("backend_auto", Json::Arr(auto_json))
            .set("cache", Json::Arr(cache_json))
            .encode()
    );
    if smoke {
        // CI smoke stops here: the codec/pruning/optimizer/exchange
        // gates above are the point; the latency sweeps below are
        // nightly-bench material.
        if failed {
            std::process::exit(1);
        }
        return;
    }
    println!();

    println!("## A1 — SQS vs S3 shuffle (the Qubole design alternative, §V/§VI)\n");
    println!("| query (groups) | backend+schedule | latency (s) | cost (USD) | shuffle msgs |");
    println!("|---|---|---|---|---|");
    for q in [QueryId::Q1, QueryId::Q4, QueryId::Q5, QueryId::Q6, QueryId::Q6J] {
        let rows = shuffle_ablation(&cfg, trips, q).expect("bench");
        for (name, lat, cost, msgs) in rows {
            println!(
                "| {} ({}) | {name} | {lat:.2} | {cost:.4} | {msgs} |",
                q,
                q.intermediate_groups()
            );
        }
    }
    // A5 — broadcast-vs-shuffle join crossover on the Q6/Q6J pair:
    // sweep the dimension-table size and record where the exchange
    // operator starts beating the per-map-task broadcast read.
    println!("\n## A5 — broadcast (Q6) vs shuffle join (Q6J): dimension-size sweep\n");
    println!("| dim table (B) | broadcast Q6 (s) | shuffle Q6J (s) | Q6 $ | Q6J $ |");
    println!("|---|---|---|---|---|");
    let sweep: Vec<u64> = vec![
        0,
        1024 * 1024,
        4 * 1024 * 1024,
        16 * 1024 * 1024,
        64 * 1024 * 1024,
    ];
    let (rows, crossover) = join_crossover(&cfg, trips.min(100_000), &sweep).expect("crossover");
    let mut json_rows = Vec::new();
    for r in &rows {
        println!(
            "| {} | {:.2} | {:.2} | {:.4} | {:.4} |",
            r.dim_bytes, r.broadcast_s, r.shuffle_s, r.broadcast_usd, r.shuffle_usd
        );
        json_rows.push(
            Json::obj()
                .set("dim_bytes", r.dim_bytes)
                .set("broadcast_s", r.broadcast_s)
                .set("shuffle_s", r.shuffle_s)
                .set("broadcast_usd", r.broadcast_usd)
                .set("shuffle_usd", r.shuffle_usd),
        );
    }
    let mut json = Json::obj()
        .set("bench", "join_crossover")
        .set("rows", Json::Arr(json_rows));
    json = match crossover {
        Some(b) => json.set("crossover_dim_bytes", b),
        None => json.set("crossover_dim_bytes", Json::Null),
    };
    println!("\n{}", json.encode());
    match crossover {
        Some(b) => println!("\n(crossover: the shuffle join starts winning at a ~{b} B dimension table)"),
        None => println!("\n(no crossover in this sweep: broadcast won throughout)"),
    }

    println!("\n(Q6J routes the weather join through the shuffle itself — two scan");
    println!(" stages fan into a KernelJoin stage — so its rows price the exchange");
    println!(" operator on each backend, not just the aggregation shuffle.");
    println!(" SQS wins on small intermediate groups — the paper's design bet;");
    println!(" S3's per-object first-byte latency dominates its shuffle at this shape.");
    println!(" Pipelined scheduling hides SQS reduce drain behind map flushes, so");
    println!(" sqs+pipelined must undercut sqs+barrier; the S3 backend's one-shot");
    println!(" list-then-get shuffle cannot overlap and has no pipelined row.)");
    if failed {
        std::process::exit(1);
    }
}
