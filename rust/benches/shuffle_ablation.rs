//! `cargo bench --bench shuffle_ablation` — experiment A1 (DESIGN.md
//! §6): the §VI future-work comparison between Flint's SQS shuffle and
//! Qubole's S3 shuffle, swept over query group counts — each backend
//! under both the serial barrier clock and the pipelined DAG scheduler
//! (both latencies come from the same execution, so the pair is exact).

use flint::bench::micro::{join_crossover, shuffle_ablation};
use flint::compute::queries::QueryId;
use flint::config::FlintConfig;
use flint::util::json::Json;

fn main() {
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 8 * 1024 * 1024;
    cfg.flint.input_split_bytes = 8 * 1024 * 1024;

    let trips = std::env::var("FLINT_BENCH_TRIPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400_000);

    println!("## A1 — SQS vs S3 shuffle (the Qubole design alternative, §V/§VI)\n");
    println!("| query (groups) | backend+schedule | latency (s) | cost (USD) | shuffle msgs |");
    println!("|---|---|---|---|---|");
    for q in [QueryId::Q1, QueryId::Q4, QueryId::Q5, QueryId::Q6, QueryId::Q6J] {
        let rows = shuffle_ablation(&cfg, trips, q).expect("bench");
        for (name, lat, cost, msgs) in rows {
            println!(
                "| {} ({}) | {name} | {lat:.2} | {cost:.4} | {msgs} |",
                q,
                q.intermediate_groups()
            );
        }
    }
    // A5 — broadcast-vs-shuffle join crossover on the Q6/Q6J pair:
    // sweep the dimension-table size and record where the exchange
    // operator starts beating the per-map-task broadcast read.
    println!("\n## A5 — broadcast (Q6) vs shuffle join (Q6J): dimension-size sweep\n");
    println!("| dim table (B) | broadcast Q6 (s) | shuffle Q6J (s) | Q6 $ | Q6J $ |");
    println!("|---|---|---|---|---|");
    let sweep: Vec<u64> = vec![
        0,
        1024 * 1024,
        4 * 1024 * 1024,
        16 * 1024 * 1024,
        64 * 1024 * 1024,
    ];
    let (rows, crossover) = join_crossover(&cfg, trips.min(100_000), &sweep).expect("crossover");
    let mut json_rows = Vec::new();
    for r in &rows {
        println!(
            "| {} | {:.2} | {:.2} | {:.4} | {:.4} |",
            r.dim_bytes, r.broadcast_s, r.shuffle_s, r.broadcast_usd, r.shuffle_usd
        );
        json_rows.push(
            Json::obj()
                .set("dim_bytes", r.dim_bytes)
                .set("broadcast_s", r.broadcast_s)
                .set("shuffle_s", r.shuffle_s)
                .set("broadcast_usd", r.broadcast_usd)
                .set("shuffle_usd", r.shuffle_usd),
        );
    }
    let mut json = Json::obj()
        .set("bench", "join_crossover")
        .set("rows", Json::Arr(json_rows));
    json = match crossover {
        Some(b) => json.set("crossover_dim_bytes", b),
        None => json.set("crossover_dim_bytes", Json::Null),
    };
    println!("\n{}", json.encode());
    match crossover {
        Some(b) => println!("\n(crossover: the shuffle join starts winning at a ~{b} B dimension table)"),
        None => println!("\n(no crossover in this sweep: broadcast won throughout)"),
    }

    println!("\n(Q6J routes the weather join through the shuffle itself — two scan");
    println!(" stages fan into a KernelJoin stage — so its rows price the exchange");
    println!(" operator on each backend, not just the aggregation shuffle.");
    println!(" SQS wins on small intermediate groups — the paper's design bet;");
    println!(" S3's per-object first-byte latency dominates its shuffle at this shape.");
    println!(" Pipelined scheduling hides SQS reduce drain behind map flushes, so");
    println!(" sqs+pipelined must undercut sqs+barrier; the S3 backend's one-shot");
    println!(" list-then-get shuffle cannot overlap and has no pipelined row.)");
}
