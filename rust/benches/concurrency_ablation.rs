//! `cargo bench --bench concurrency_ablation [-- --smoke]` — experiment
//! A8: multi-tenant service throughput and tail latency by arbitration
//! policy.
//!
//! `n` tenants each burst one copy of a two-stage query (scan → 4-way
//! reduce, narrower than the slot pool) at the service; the sweep
//! crosses burst size with `flint.service.policy`. FIFO's head-of-line
//! blocking leaves slots idle and stretches the latency tail; fair
//! sharing packs the same work (work conservation — the makespan, and
//! so throughput, must not regress) while every tenant progresses, so
//! p99 collapses toward p50. `--smoke` mode (CI) runs a tiny
//! deterministic dataset (`compute_scale = 0`) and exits non-zero if
//! fair stops beating FIFO's p99 at 4 concurrent queries, or if fair
//! throughput regresses against FIFO or against serial execution.

use flint::bench::micro::concurrency_ablation;
use flint::config::FlintConfig;
use flint::simtime::ServicePolicy;
use flint::util::json::Json;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    if smoke {
        // CI-sized and fully modeled (`compute_scale = 0`): identical
        // queries get identical durations, so the policy gates below
        // compare schedules, not host noise. 4 scan tasks + 4 reduce
        // tasks per query on an 8-slot pool — per-query width stays
        // below the pool, which is exactly the regime where arbitration
        // (not raw capacity) decides the tail.
        cfg.data.object_bytes = 128 * 1024;
        cfg.flint.input_split_bytes = 128 * 1024;
        cfg.flint.use_pjrt = false;
        cfg.sim.max_concurrency = 8;
        cfg.sim.compute_scale = 0.0;
    } else {
        cfg.data.object_bytes = 2 * 1024 * 1024;
        cfg.flint.input_split_bytes = 2 * 1024 * 1024;
    }
    let trips = std::env::var("FLINT_BENCH_TRIPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 5_000 } else { 100_000 });

    let concurrency: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let policies = [ServicePolicy::Fifo, ServicePolicy::Fair];

    println!("## A8 — multi-tenant concurrency: policy vs throughput and tail\n");
    println!("| queries | policy | makespan (s) | p50 (s) | p99 (s) | throughput (q/s) | idle (s) | cost (USD) |");
    println!("|---|---|---|---|---|---|---|---|");
    let rows = concurrency_ablation(&cfg, trips, concurrency, &policies).expect("bench");
    let mut json_rows = Vec::new();
    for r in &rows {
        println!(
            "| {} | {} | {:.2} | {:.2} | {:.2} | {:.3} | {:.2} | {:.4} |",
            r.queries,
            r.policy.name(),
            r.makespan_s,
            r.p50_s,
            r.p99_s,
            r.throughput_qps,
            r.idle_s,
            r.cost_usd
        );
        json_rows.push(
            Json::obj()
                .set("queries", r.queries as u64)
                .set("policy", r.policy.name())
                .set("makespan_s", r.makespan_s)
                .set("p50_s", r.p50_s)
                .set("p99_s", r.p99_s)
                .set("throughput_qps", r.throughput_qps)
                .set("idle_s", r.idle_s)
                .set("cost_usd", r.cost_usd),
        );
    }
    println!(
        "\n{}",
        Json::obj()
            .set("bench", "concurrency_ablation")
            .set("trips", trips)
            .set("rows", Json::Arr(json_rows))
            .encode()
    );
    println!("\n(Fair sharing does not add capacity — it re-orders grants, so the");
    println!(" makespan is pinned by work conservation while FIFO's last tenant");
    println!(" stops paying for every query ahead of it in line.)");

    let cell = |n: usize, p: ServicePolicy| {
        rows.iter()
            .find(|r| r.queries == n && r.policy == p)
            .unwrap_or_else(|| panic!("missing cell ({n}, {})", p.name()))
    };
    let mut failed = false;
    let fifo4 = cell(4, ServicePolicy::Fifo);
    let fair4 = cell(4, ServicePolicy::Fair);
    let serial = cell(1, ServicePolicy::Fair);
    if fair4.p99_s >= fifo4.p99_s {
        eprintln!(
            "REGRESSION: fair p99 {:.3}s did not beat fifo p99 {:.3}s at 4 queries",
            fair4.p99_s, fifo4.p99_s
        );
        failed = true;
    }
    if fair4.throughput_qps < fifo4.throughput_qps - 1e-9 {
        eprintln!(
            "REGRESSION: fair throughput {:.4} q/s below fifo {:.4} q/s",
            fair4.throughput_qps, fifo4.throughput_qps
        );
        failed = true;
    }
    if fair4.throughput_qps < serial.throughput_qps - 1e-9 {
        eprintln!(
            "REGRESSION: fair throughput {:.4} q/s at 4 queries below serial {:.4} q/s",
            fair4.throughput_qps, serial.throughput_qps
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
}
