//! `cargo bench --bench straggler_ablation [-- --smoke]` — experiment
//! A4: speculative execution under injected heavy-tailed stragglers.
//!
//! Each query runs ONCE with a forced 10x straggler in its scan stage
//! and speculation enabled; the driver reports the speculative and the
//! speculation-free pipelined clocks from that same execution, so the
//! comparison is exact. Pipelined+speculation must strictly beat plain
//! pipelined on every multi-stage query — `--smoke` mode (CI) runs a
//! small dataset and exits non-zero on any regression, so speculation
//! breakage fails PRs instead of waiting for a nightly bench run.

use flint::bench::micro::straggler_ablation;
use flint::compute::queries::QueryId;
use flint::config::FlintConfig;
use flint::util::json::Json;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    if smoke {
        // CI-sized: tiny objects/splits so the scan still has enough
        // tasks for the tail signal's quorum, PJRT off (no artifacts in
        // CI runners).
        cfg.data.object_bytes = 512 * 1024;
        cfg.flint.input_split_bytes = 256 * 1024;
        cfg.flint.use_pjrt = false;
        cfg.sim.max_concurrency = 8;
    } else {
        cfg.data.object_bytes = 8 * 1024 * 1024;
        cfg.flint.input_split_bytes = 8 * 1024 * 1024;
    }
    let trips = std::env::var("FLINT_BENCH_TRIPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 20_000 } else { 400_000 });

    let queries = [
        QueryId::Q1,
        QueryId::Q2,
        QueryId::Q3,
        QueryId::Q4,
        QueryId::Q5,
        QueryId::Q6,
        QueryId::Q6J,
    ];
    println!("## A4 — speculative execution vs injected stragglers (10x on scan task 0)\n");
    println!("| query | pipelined+spec (s) | plain pipelined (s) | barrier (s) | idle (s) | backups | wins | cost (USD) |");
    println!("|---|---|---|---|---|---|---|---|");
    let rows = straggler_ablation(&cfg, trips, &queries).expect("bench");
    let mut failed = false;
    let mut json_rows = Vec::new();
    for r in &rows {
        println!(
            "| {} | {:.2} | {:.2} | {:.2} | {:.2} | {} | {} | {:.4} |",
            r.query,
            r.spec_pipelined_s,
            r.plain_pipelined_s,
            r.barrier_s,
            r.idle_s,
            r.launches,
            r.wins,
            r.cost_usd
        );
        if r.spec_pipelined_s >= r.plain_pipelined_s {
            eprintln!(
                "REGRESSION: {} speculation {:.3}s did not beat plain pipelined {:.3}s",
                r.query, r.spec_pipelined_s, r.plain_pipelined_s
            );
            failed = true;
        }
        json_rows.push(
            Json::obj()
                .set("query", r.query.name())
                .set("spec_pipelined_s", r.spec_pipelined_s)
                .set("plain_pipelined_s", r.plain_pipelined_s)
                .set("barrier_s", r.barrier_s)
                .set("idle_s", r.idle_s)
                .set("speculative_launches", r.launches)
                .set("speculative_wins", r.wins)
                .set("cost_usd", r.cost_usd),
        );
    }
    println!(
        "\n{}",
        Json::obj()
            .set("bench", "straggler_ablation")
            .set("trips", trips)
            .set("rows", Json::Arr(json_rows))
            .encode()
    );
    println!("\n(Every attempt bills its GB-seconds — the loser too, Lambda has no");
    println!(" mid-flight cancellation — and pipelined long-polling bills idle time,");
    println!(" so these rows price exactly what the latency win costs.)");
    if failed {
        std::process::exit(1);
    }
}
