//! `cargo bench --bench cold_start` — experiment M2 (DESIGN.md §6): the
//! §III-B claims that (a) Python Lambdas start fast enough to give each
//! task its own invocation and (b) "the cost of using chained executors
//! is relatively low".

use flint::bench::micro::cold_warm_chain;
use flint::config::FlintConfig;

fn main() {
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 8 * 1024 * 1024;
    cfg.flint.input_split_bytes = 8 * 1024 * 1024;

    let trips = std::env::var("FLINT_BENCH_TRIPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400_000);
    let (cold, warm, chained, unchained, links) = cold_warm_chain(&cfg, trips).expect("bench");

    println!("## M2 — cold vs warm starts, chaining overhead\n");
    println!("| condition | latency (s) |");
    println!("|---|---|");
    println!("| Q0, cold container pool | {cold:.2} |");
    println!("| Q0, warm container pool | {warm:.2} |");
    println!(
        "| warm-up saving | {:.2}s ({:.1}%) |",
        cold - warm,
        (1.0 - warm / cold) * 100.0
    );
    println!("| Q1, duration-capped ({links} chain links) | {chained:.2} |");
    println!("| Q1, uncapped (no chaining) | {unchained:.2} |");
    println!(
        "| chaining overhead | {:+.1}% |",
        (chained / unchained - 1.0) * 100.0
    );
    println!(
        "\nconfig: cold start {:.0} ms, warm start {:.0} ms (Python-runtime figures, §III-B)",
        cfg.sim.lambda_cold_start_s * 1e3,
        cfg.sim.lambda_warm_start_s * 1e3
    );
}
