//! `cargo bench --bench s3_throughput` — experiment M1 (DESIGN.md §6):
//! the §IV in-text microbenchmark isolating S3 read throughput, the
//! paper's explanation for Flint beating Spark on Q0 ("the Python
//! library that we use (boto) achieves much better throughput than the
//! library that Spark uses").

use flint::bench::micro::s3_throughput;
use flint::config::FlintConfig;

fn main() {
    let cfg = FlintConfig::default();
    println!("## M1 — single-stream S3 read throughput (modeled profiles)\n");
    println!("| object | flint/boto MB/s | spark/hadoop MB/s | ratio |");
    println!("|---|---|---|---|");
    for mb in [1usize, 8, 64, 256, 1024] {
        let (f, s) = s3_throughput(&cfg, mb).expect("bench");
        println!("| {mb} MiB | {f:.1} | {s:.1} | {:.2}x |", f / s);
    }
    println!(
        "\npaper-effective rates at 64 MiB splits: flint {:.1} MB/s, spark {:.1} MB/s",
        cfg.sim.s3_flint_mbps, cfg.sim.s3_spark_mbps
    );
    println!("(calibrated from Q0: 215 GB / 80 workers / 101 s vs 188 s — DESIGN.md §5)");
}
