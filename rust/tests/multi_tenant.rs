//! The multi-tenant service layer, end to end:
//!
//! 1. **Conservation** — per-tenant [`CostLedger`]s are exact: N
//!    non-contending tenants' ledgers each equal an independent
//!    single-tenant run bit-for-bit, and they sum to the pool's total
//!    billed spend.
//! 2. **Identity** — a single-query service run reproduces the solo
//!    engine's schedule and bill exactly (the tentpole's "byte-identical
//!    when unused" contract, from the service side).
//! 3. **Admission** — the bounded queue rejects with a *typed* error.
//! 4. **Fairness** — under saturation, `fair` splits the pool within
//!    one task of N/num_tenants (observed through latencies) and beats
//!    FIFO's tail; `weighted` prioritizes heavy tenants.
//! 5. **Prediction** — per-container history suppresses backups for
//!    threshold-crossing tasks on demonstrably fast containers.
//!
//! [`CostLedger`]: flint::cost::report::CostLedger

use flint::config::FlintConfig;
use flint::data::{generate_taxi_dataset, INPUT_BUCKET};
use flint::exec::service::ServiceError;
use flint::exec::{FlintContext, FlintService};
use flint::plan::{Action, Rdd};
use flint::services::SimEnv;
use flint::simtime::{
    schedule_service, ScheduleMode, ServicePolicy, ServiceQuerySpec, StageSpec,
};

const EPS: f64 = 1e-9;

/// Fully modeled config: `compute_scale = 0` removes host-measured
/// jitter, so identical queries produce identical durations, schedules,
/// and bills — the exactness the conservation tests pin.
fn modeled_cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.sim.compute_scale = 0.0;
    c
}

/// A two-stage shuffle lineage (scan → reduce) so runs exercise queue
/// management, pipelined idle, and per-edge accounting.
fn hour_histogram(sc: &FlintContext) -> Rdd {
    sc.text_file(INPUT_BUCKET, "trips/")
        .map(|line| {
            let text = line.as_str().expect("text input");
            let hour = flint::data::schema::TripRecord::parse_csv(text.as_bytes())
                .map(|r| flint::data::chrono::hour_of_day(r.dropoff_ts) as i64)
                .unwrap_or(0);
            flint::compute::value::Value::pair(
                flint::compute::value::Value::I64(hour),
                flint::compute::value::Value::I64(1),
            )
        })
        .reduce_by_key(8, |a, b| {
            flint::compute::value::Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
        })
}

/// One standalone single-tenant run of the same lineage: the ledger
/// ground truth. Returns (cost_usd, gb_seconds, idle_s, latency_s).
fn solo_run(cfg: &FlintConfig) -> (f64, f64, f64, f64) {
    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let sc = FlintContext::new(env.clone());
    sc.prewarm();
    let report = sc.run(&hour_histogram(&sc), Action::Collect).unwrap();
    let gb_s = report.cost.get(flint::cost::CostCategory::LambdaCompute)
        / cfg.pricing.lambda_gb_s;
    (report.cost_usd, gb_s, report.pipelined_idle_s, report.latency_s)
}

#[test]
fn ledgers_conserve_across_non_contending_tenants() {
    let cfg = modeled_cfg();
    let (solo_usd, solo_gb_s, solo_idle, _) = solo_run(&cfg);
    assert!(solo_usd > 0.0, "solo run must bill something");

    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let service = FlintService::new(env.clone());
    service.prewarm();
    let sc = service.session("anyone");
    let rdd = hour_histogram(&sc);
    // Arrivals far apart: no two queries ever contend for a slot, so
    // each runs its exact solo schedule on the shared clock.
    for (i, tenant) in ["acme", "globex", "initech"].iter().enumerate() {
        service
            .submit_at(tenant, &rdd, Action::Collect, i as f64 * 10_000.0)
            .unwrap();
    }
    let report = service.run().unwrap();

    // Σ ledgers == the pool's billed spend, to the last bit.
    let ledger_sum: f64 = report.ledgers.values().map(|l| l.total_usd()).sum();
    assert!(
        (ledger_sum - report.run_cost.total()).abs() < 1e-15,
        "ledgers {ledger_sum} != pool {}",
        report.run_cost.total()
    );
    // And each tenant's ledger equals its independent single-tenant run.
    assert_eq!(report.ledgers.len(), 3);
    for (tenant, ledger) in &report.ledgers {
        assert_eq!(ledger.queries, 1, "{tenant}");
        assert!(
            (ledger.total_usd() - solo_usd).abs() < EPS,
            "{tenant}: ledger ${} != solo ${solo_usd}",
            ledger.total_usd()
        );
        assert!(
            (ledger.gb_seconds - solo_gb_s).abs() < EPS,
            "{tenant}: {} GB-s != solo {solo_gb_s}",
            ledger.gb_seconds
        );
        assert!(
            (ledger.idle_s - solo_idle).abs() < EPS,
            "{tenant}: idle {} != solo {solo_idle}",
            ledger.idle_s
        );
    }
    // The rendered table is deterministic and carries every tenant.
    let table = report.render_ledgers();
    for tenant in ["acme", "globex", "initech"] {
        assert!(table.contains(tenant), "{table}");
    }
}

#[test]
fn single_query_service_run_matches_solo_engine_exactly() {
    let cfg = modeled_cfg();
    let (solo_usd, _, _, solo_latency) = solo_run(&cfg);

    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let service = FlintService::new(env.clone());
    service.prewarm();
    let sc = service.session("acme");
    service.submit("acme", &hour_histogram(&sc), Action::Collect).unwrap();
    let report = service.run().unwrap();

    let q = &report.queries[0];
    assert!(
        (q.window.latency_s - solo_latency).abs() < EPS,
        "service latency {} != solo {solo_latency}",
        q.window.latency_s
    );
    assert!(
        (q.cost.total() - solo_usd).abs() < EPS,
        "service cost {} != solo {solo_usd}",
        q.cost.total()
    );
    // Per-query metric namespace exists, service-internal meters stay
    // global, and the tenant rollup mirrors the query's namespace.
    let m = env.metrics();
    assert!(m.get("q0.lambda.invocations") == 0, "service meters must stay global");
    assert!(m.get("lambda.invocations") > 0);
    let edge = "shuffle.edge.s0-s1.msgs";
    assert!(m.get(&format!("q0.{edge}")) > 0, "query-scoped driver metrics");
    assert_eq!(
        m.get(&format!("tenant.acme.{edge}")),
        m.get(&format!("q0.{edge}")),
        "tenant rollup mirrors the query scope"
    );
}

#[test]
fn admission_queue_rejects_with_typed_error() {
    let mut cfg = modeled_cfg();
    cfg.flint.service.max_queued = 2;
    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let service = FlintService::new(env);
    let sc = service.session("acme");
    let rdd = hour_histogram(&sc);
    service.submit("acme", &rdd, Action::Count).unwrap();
    service.submit("globex", &rdd, Action::Count).unwrap();
    let err = service.submit("initech", &rdd, Action::Count).unwrap_err();
    assert_eq!(err, ServiceError::QueueFull { queued: 2, limit: 2 });
    assert!(err.to_string().contains("max_queued"), "{err}");
    // Draining the queue re-opens admission.
    service.run().unwrap();
    assert_eq!(service.queued(), 0);
    service.submit("initech", &rdd, Action::Count).unwrap();
}

/// `n` copies of an equal one-stage query: `tasks` × 1 s each.
fn equal_queries(n: usize, tasks: usize, weight: f64) -> Vec<ServiceQuerySpec> {
    (0..n)
        .map(|_| ServiceQuerySpec {
            stages: vec![StageSpec {
                id: 0,
                parents: vec![],
                task_durations: vec![1.0; tasks],
                backups: vec![],
                overhead_s: 0.0,
            }],
            arrival_s: 0.0,
            weight,
            quota: None,
        })
        .collect()
}

#[test]
fn fair_splits_the_pool_within_one_task_and_beats_fifo_tail() {
    // 4 queries × 4 tasks on 8 slots: each query alone uses half the
    // pool, so FIFO head-of-line blocking wastes slots while fair
    // packs them.
    let queries = equal_queries(4, 4, 1.0);
    let fifo =
        schedule_service(&queries, 8, ScheduleMode::Pipelined, ServicePolicy::Fifo, None);
    let fair =
        schedule_service(&queries, 8, ScheduleMode::Pipelined, ServicePolicy::Fair, None);
    let fifo_worst =
        fifo.queries.iter().map(|w| w.latency_s).fold(0.0_f64, f64::max);
    let fair_worst =
        fair.queries.iter().map(|w| w.latency_s).fold(0.0_f64, f64::max);
    assert!(
        fair_worst + EPS < fifo_worst,
        "fair tail {fair_worst} must beat fifo tail {fifo_worst}"
    );
    // Work conservation: total work 16 task-seconds over 8 slots.
    assert!((fair.makespan_s - 2.0).abs() < EPS, "{}", fair.makespan_s);

    // Saturation fairness bound: 2 queries that could each fill the
    // pool get N/num_tenants slots each, so equal work finishes within
    // one task duration of each other — no tenant starves.
    let sat = equal_queries(2, 8, 1.0);
    let out = schedule_service(&sat, 8, ScheduleMode::Pipelined, ServicePolicy::Fair, None);
    let l0 = out.queries[0].latency_s;
    let l1 = out.queries[1].latency_s;
    assert!((l0 - l1).abs() <= 1.0 + EPS, "fair split: {l0} vs {l1}");
    assert!((out.makespan_s - 2.0).abs() < EPS, "work-conserving: {}", out.makespan_s);
    assert!(l0.max(l1) <= 2.0 + EPS, "neither tenant exceeds its share for long");
}

#[test]
fn weighted_policy_prioritizes_heavy_tenants() {
    // Same demand, weights 3 vs 1: the heavy tenant holds ~3/4 of the
    // pool under contention and must finish strictly first. (Enough
    // work per query that the steady-state share dominates the finish
    // times — tiny queries all end on the same round.)
    let mut queries = equal_queries(2, 24, 1.0);
    queries[0].weight = 3.0;
    let out =
        schedule_service(&queries, 8, ScheduleMode::Pipelined, ServicePolicy::Weighted, None);
    let heavy = out.queries[0].latency_s;
    let light = out.queries[1].latency_s;
    assert!(
        heavy + EPS < light,
        "weight-3 tenant ({heavy}s) must beat weight-1 ({light}s)"
    );
}

#[test]
fn predictor_suppresses_backups_on_demonstrably_fast_containers() {
    let mut cfg = modeled_cfg();
    cfg.sim.straggler_containers = 64; // container-affinity mode
    cfg.flint.speculation.enabled = true;
    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let service = FlintService::new(env.clone());
    service.prewarm();
    let sc = service.session("acme");
    let rdd = hour_histogram(&sc);

    // Query 0: clean run — builds per-container history (every
    // container observed near ratio 1.0).
    service.submit("acme", &rdd, Action::Collect).unwrap();
    let first = service.run().unwrap();
    assert_eq!(first.queries[0].speculative_launches, 0);
    assert!(service.predictor().containers_seen() > 0);

    // Query 1: the same scan task is forced 10× slower. The tail signal
    // fires, but its container's history says "not slow" — slow work,
    // not a slow node — so the backup is suppressed.
    env.failure().force_straggler(0, 0, 0, 10.0);
    service.submit("acme", &rdd, Action::Collect).unwrap();
    let second = service.run().unwrap();
    assert_eq!(
        second.queries[0].speculative_launches, 0,
        "backup must be suppressed by container history"
    );
    assert!(
        env.metrics().get("q1.scheduler.speculative_suppressed") >= 1,
        "suppression is metered: {:?}",
        env.metrics().snapshot()
    );
}

#[test]
fn service_knobs_unset_leave_single_query_runs_identical() {
    // The regression pin for "byte-identical when unused": two fresh
    // environments with the service knobs at their defaults produce
    // identical reports, and nothing leaks service namespaces into the
    // metrics registry.
    let cfg = modeled_cfg();
    assert_eq!(cfg.flint.service, flint::config::ServiceParams::default());
    let run = || {
        let env = SimEnv::new(cfg.clone());
        generate_taxi_dataset(&env, "trips", cfg.data.trips);
        let sc = FlintContext::new(env.clone());
        sc.prewarm();
        let report = sc.run(&hour_histogram(&sc), Action::Collect).unwrap();
        let metrics = env.metrics().snapshot();
        (format!("{report:?}"), metrics)
    };
    let (a, am) = run();
    let (b, bm) = run();
    assert_eq!(a, b, "single-query reports must be deterministic");
    assert_eq!(am, bm, "metrics must be deterministic");
    assert!(
        am.iter().all(|(k, _)| !k.starts_with("q0.") && !k.starts_with("tenant.")),
        "no service namespaces on the single-query path: {am:?}"
    );
}
