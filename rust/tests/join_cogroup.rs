//! True joins/cogroup over the per-parent-tagged shuffle. Q6J (trips ⋈
//! weather on the day key) must produce exactly the broadcast-Q6
//! oracle's answer on every shuffle backend (sqs/s3/memory), under both
//! schedulers, with SQS duplicate injection enabled, and across forced
//! reducer crashes/retries — §VI exactly-once, now across *tagged*
//! parent streams. The generic `Rdd::cogroup`/`Rdd::join` API lowers to
//! the same plan shape and is held to the same oracle.

use flint::compute::oracle;
use flint::compute::queries::{QueryId, QueryResult};
use flint::compute::value::Value;
use flint::config::{FlintConfig, ShuffleBackend};
use flint::data::chrono::day_index;
use flint::data::schema::TripRecord;
use flint::data::weather::precip_bucket;
use flint::data::{generate_taxi_dataset, Dataset, INPUT_BUCKET};
use flint::exec::driver::{run_plan, RunParams};
use flint::exec::executor::IoMode;
use flint::exec::shuffle::{MemoryShuffle, Transport};
use flint::exec::{ClusterEngine, ClusterMode, Engine, FlintContext, FlintEngine};
use flint::plan::{build_union_plan, dag, Action, DynOp, Rdd, UnionBranch};
use flint::services::SimEnv;
use flint::simtime::ScheduleMode;
use std::collections::BTreeMap;
use std::sync::Arc;

const TRIPS: u64 = 25_000;

fn cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 512 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false;
    c
}

fn setup(c: FlintConfig) -> (SimEnv, Dataset) {
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    (env, ds)
}

#[test]
fn q6j_matches_oracle_on_sqs_and_s3_under_both_schedulers_with_duplicates() {
    for backend in [ShuffleBackend::Sqs, ShuffleBackend::S3] {
        for sched in [ScheduleMode::Barrier, ScheduleMode::Pipelined] {
            let mut c = cfg();
            c.flint.shuffle_backend = backend;
            c.flint.scheduler = sched;
            c.sim.sqs_duplicate_prob = 0.2; // at-least-once, aggressively
            let (env, ds) = setup(c);
            let flint = FlintEngine::new(env.clone());
            flint.prewarm();
            let expect = oracle::evaluate(&env, &ds, QueryId::Q6J);
            let report = flint.run_query(QueryId::Q6J, &ds).unwrap();
            assert!(
                report.result.approx_eq(&expect),
                "{backend:?}/{sched:?}: {:?} vs {expect:?}",
                report.result
            );
            // The join answer IS the broadcast answer.
            let q6 = oracle::evaluate(&env, &ds, QueryId::Q6);
            assert!(report.result.approx_eq(&q6), "join must equal broadcast Q6");
            assert_eq!(report.stage_latencies.len(), 4, "scan+scan -> join -> reduce");
            if backend == ShuffleBackend::Sqs {
                assert!(report.duplicates_dropped > 0, "dedup must have fired");
                // The DAG fanned in and chained: three shuffle edges.
                let edges: Vec<(u32, u32)> =
                    report.edge_shuffle.iter().map(|e| (e.from, e.to)).collect();
                assert_eq!(edges, vec![(0, 2), (1, 2), (2, 3)], "{:?}", report.edge_shuffle);
                assert!(report.edge_shuffle.iter().all(|e| e.msgs > 0));
                // Pipelined never schedules worse than barrier, even on
                // the join's multi-root diamond (serial-fallback guard).
                assert!(
                    report.pipelined_latency_s <= report.barrier_latency_s + 1e-9,
                    "pipelined {:.4}s vs barrier {:.4}s",
                    report.pipelined_latency_s,
                    report.barrier_latency_s
                );
                assert_eq!(env.sqs().queue_names().len(), 0, "queues refcount-deleted");
            }
        }
    }
}

#[test]
fn q6j_matches_oracle_on_the_memory_backend() {
    // Cluster engines run the same join plan over the in-process shuffle.
    let (env, ds) = setup(cfg());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q6J);
    for mode in [ClusterMode::Spark, ClusterMode::PySpark] {
        let engine = ClusterEngine::new(env.clone(), mode);
        let report = engine.run_query(QueryId::Q6J, &ds).unwrap();
        assert!(
            report.result.approx_eq(&expect),
            "{mode:?}: {:?} vs {expect:?}",
            report.result
        );
    }
    // And directly under the pipelined clock (the cluster engine pins
    // barrier; the scheduler itself must handle memory + overlap).
    let plan = flint::plan::kernel_plan(QueryId::Q6J, &ds, env.config());
    let params = RunParams {
        mode: IoMode::Spark,
        transport: Transport::Memory(MemoryShuffle::new()),
        slots: 16,
        lambda: false,
        host_parallelism: 4,
        schedule: ScheduleMode::Pipelined,
        bill_idle: true,
        predictor: None,
    };
    let out = run_plan(&env, None, &plan, &params).unwrap();
    let result = out.out.to_query_result().unwrap();
    assert!(result.approx_eq(&expect), "memory+pipelined: {result:?}");
    assert!(out.pipelined_latency_s <= out.barrier_latency_s + 1e-9);
}

#[test]
fn q6j_survives_forced_join_and_reduce_crashes_on_sqs() {
    let mut c = cfg();
    c.sim.sqs_duplicate_prob = 0.15;
    let (env, ds) = setup(c);
    // Crash one join task and one final-reduce task on their first
    // attempts: both must nack their in-flight messages and the retries
    // must rebuild identical per-edge state.
    env.failure().force_task_failure(2, 0, 0);
    env.failure().force_task_failure(3, 0, 0);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q6J);
    let report = flint.run_query(QueryId::Q6J, &ds).unwrap();
    assert_eq!(report.retries, 2, "both forced crashes fired");
    assert!(report.result.approx_eq(&expect), "{:?} vs {expect:?}", report.result);
    assert!(env.metrics().get("sqs.nacked") > 0, "visibility-timeout path exercised");
}

#[test]
fn q6j_survives_forced_crashes_on_s3_and_memory_backends() {
    // S3: objects persist until the scheduler tears the prefix down, so
    // a crashed join task's retry simply re-lists them.
    let mut c = cfg();
    c.flint.shuffle_backend = ShuffleBackend::S3;
    let (env, ds) = setup(c);
    env.failure().force_task_failure(2, 1, 0);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q6J);
    let report = flint.run_query(QueryId::Q6J, &ds).unwrap();
    assert_eq!(report.retries, 1);
    assert!(report.result.approx_eq(&expect));

    // Memory: the backend's new visibility semantics redeliver the
    // drained partition to the retry (it used to be silently lost).
    let (env2, ds2) = setup(cfg());
    env2.failure().force_task_failure(2, 1, 0);
    let plan = flint::plan::kernel_plan(QueryId::Q6J, &ds2, env2.config());
    let params = RunParams {
        mode: IoMode::Spark,
        transport: Transport::Memory(MemoryShuffle::new()),
        slots: 16,
        lambda: false,
        host_parallelism: 4,
        schedule: ScheduleMode::Barrier,
        bill_idle: true,
        predictor: None,
    };
    let out = run_plan(&env2, None, &plan, &params).unwrap();
    assert_eq!(out.retries, 1);
    let expect2 = oracle::evaluate(&env2, &ds2, QueryId::Q6J);
    let result = out.out.to_query_result().unwrap();
    assert!(result.approx_eq(&expect2), "memory crash/retry: {result:?} vs {expect2:?}");
}

/// Trips as `(day, 1)` pairs for the generic join.
fn trips_day_rdd(sc: &FlintContext) -> Rdd {
    sc.text_file(INPUT_BUCKET, "trips/").flat_map(|v| {
        let Some(line) = v.as_str() else { return Vec::new() };
        match TripRecord::parse_csv(line.as_bytes()) {
            Some(r) => vec![Value::pair(
                Value::I64(day_index(r.dropoff_ts) as i64),
                Value::I64(1),
            )],
            None => Vec::new(),
        }
    })
}

/// The weather CSV as `(day, precip_bucket)` pairs.
fn weather_bucket_rdd(sc: &FlintContext) -> Rdd {
    sc.text_file(INPUT_BUCKET, "weather/").flat_map(|v| {
        let Some(line) = v.as_str() else { return Vec::new() };
        let Some((d, p)) = line.split_once(',') else { return Vec::new() };
        let (Ok(d), Ok(p)) = (d.trim().parse::<i64>(), p.trim().parse::<f32>()) else {
            return Vec::new();
        };
        vec![Value::pair(Value::I64(d), Value::I64(precip_bucket(p) as i64))]
    })
}

#[test]
fn generic_rdd_join_matches_q6j_oracle_under_duplicates_and_crash() {
    let mut c = cfg();
    c.sim.sqs_duplicate_prob = 0.2;
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", 6_000);
    // Crash the cogroup stage's first task once.
    env.failure().force_task_failure(2, 0, 0);
    let sc = FlintContext::new(env.clone());
    // trips ⋈ weather on day: each joined record is
    // (day, (1, bucket)); bucket counts must equal the Q6J oracle's.
    let joined = trips_day_rdd(&sc).join(&weather_bucket_rdd(&sc), 8);
    let values = joined.collect().unwrap();
    let mut counts: BTreeMap<i64, i64> = BTreeMap::new();
    for v in &values {
        let bucket = v.val().val().as_i64().expect("joined (left, right) pair");
        *counts.entry(bucket).or_insert(0) += 1;
    }
    let QueryResult::Buckets(rows) = oracle::evaluate(&env, &ds, QueryId::Q6J) else {
        panic!("bucketed oracle")
    };
    let expect: BTreeMap<i64, i64> = rows.iter().map(|(k, _, c)| (*k, *c as i64)).collect();
    assert_eq!(counts, expect, "generic join counts match the kernel join oracle");
    assert_eq!(env.sqs().queue_names().len(), 0, "join queues refcount-deleted");
}

#[test]
fn cogroup_keeps_sides_apart() {
    // The regression the union-only reduce could not catch: with two
    // heterogeneous parents, each key's values must stay grouped by
    // origin edge instead of merging into one stream.
    let env = SimEnv::new(cfg());
    let _left = generate_taxi_dataset(&env, "lefts", 2_000);
    let _right = generate_taxi_dataset(&env, "rights", 1_000);
    let sc = FlintContext::new(env.clone());
    let left_rdd = sc.text_file(INPUT_BUCKET, "lefts/").map(|v| {
        let len = v.as_str().map(|s| s.len() as i64).unwrap_or(0);
        Value::pair(Value::I64(len % 5), Value::str("L"))
    });
    let right_rdd = sc.text_file(INPUT_BUCKET, "rights/").map(|v| {
        let len = v.as_str().map(|s| s.len() as i64).unwrap_or(0);
        Value::pair(Value::I64(len % 5), Value::I64(1))
    });
    let grouped = left_rdd.cogroup(&right_rdd, 4).collect().unwrap();
    let (mut left_total, mut right_total) = (0usize, 0usize);
    for v in &grouped {
        let Value::List(sides) = v.val() else { panic!("cogroup value: {v:?}") };
        assert_eq!(sides.len(), 2, "one list per parent edge");
        let (Value::List(l), Value::List(r)) = (&sides[0], &sides[1]) else {
            panic!("per-side lists: {sides:?}")
        };
        assert!(l.iter().all(|x| x.as_str() == Some("L")), "left side pure: {l:?}");
        assert!(r.iter().all(|x| x.as_i64() == Some(1)), "right side pure: {r:?}");
        left_total += l.len();
        right_total += r.len();
    }
    assert_eq!(left_total, 2_000, "every left row grouped exactly once");
    assert_eq!(right_total, 1_000, "every right row grouped exactly once");
}

fn length_key_ops() -> Vec<DynOp> {
    vec![DynOp::Map(Arc::new(|v: Value| {
        let len = v.as_str().map(|s| s.len() as i64).unwrap_or(0);
        Value::pair(Value::I64(len % 7), Value::I64(1))
    }))]
}

#[test]
fn union_cross_parent_dedup_does_not_alias_under_duplicates() {
    // Satellite: one dedup set is threaded through every parent edge on
    // the claim that (producer, seq) spaces never collide across stages.
    // Under aggressive duplicate injection a cross-stage alias would
    // either drop a legitimate first delivery or leak a duplicate; the
    // union total stays exact iff the spaces are disjoint.
    let mut c = cfg();
    c.sim.sqs_duplicate_prob = 0.3;
    let env = SimEnv::new(c.clone());
    let ds_a = generate_taxi_dataset(&env, "tripsa", 9_000);
    let ds_b = generate_taxi_dataset(&env, "tripsb", 7_000);
    let combine: flint::plan::rdd::CombineFn =
        Arc::new(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
    let split_bytes = c.flint.input_split_bytes;
    let plan = build_union_plan(
        vec![
            UnionBranch { ops: length_key_ops(), splits: dag::input_splits(&ds_a, split_bytes) },
            UnionBranch { ops: length_key_ops(), splits: dag::input_splits(&ds_b, split_bytes) },
        ],
        4,
        combine,
        Vec::new(),
        Action::Collect,
    );
    let params = RunParams {
        mode: IoMode::Flint,
        transport: Transport::Sqs,
        slots: env.config().sim.max_concurrency,
        lambda: true,
        host_parallelism: 4,
        schedule: ScheduleMode::Pipelined,
        bill_idle: true,
        predictor: None,
    };
    let out = run_plan(&env, None, &plan, &params).unwrap();
    assert!(out.duplicates_dropped > 0, "duplicates were injected and dropped");
    let flint::exec::ActionOut::Values(values) = &out.out else {
        panic!("collect produced {:?}", out.out)
    };
    let total: i64 = values.iter().map(|v| v.val().as_i64().unwrap()).sum();
    assert_eq!(total, 9_000 + 7_000, "exactly-once across tagged parent streams");
}
