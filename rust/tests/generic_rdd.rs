//! The generic RDD path through the session API: arbitrary
//! map/filter/flatMap/reduceByKey/cogroup lineages over dynamic values —
//! "Flint is a Spark execution engine, it supports arbitrary RDD
//! transformations" (§V). The Q1 driver program from the paper's §IV is
//! reproduced verbatim in structure here, written against
//! `FlintContext` — sources come from the context, actions run on the
//! `Rdd` itself. The shapes the old per-shape planner could not express
//! (reduceByKey downstream of a cogroup, shared-sublineage diamonds,
//! outer joins) are held to the single-threaded interpreter oracle on
//! every shuffle backend.

use flint::compute::value::Value;
use flint::config::{FlintConfig, ShuffleBackend};
use flint::data::schema::{TripRecord, GOLDMAN};
use flint::data::{generate_taxi_dataset, Dataset, INPUT_BUCKET, OUTPUT_BUCKET};
use flint::exec::driver::{run_plan, ActionOut, RunParams};
use flint::exec::executor::IoMode;
use flint::exec::shuffle::{MemoryShuffle, Transport};
use flint::exec::{ClusterMode, FlintContext};
use flint::plan::{interp, Action, Rdd};
use flint::services::SimEnv;
use flint::simtime::ScheduleMode;

const TRIPS: u64 = 15_000;

fn cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 512 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false;
    c
}

fn setup() -> (SimEnv, Dataset) {
    let env = SimEnv::new(cfg());
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    (env, ds)
}

/// The paper's Q1, written against the generic session API:
/// ```python
/// src.map(lambda x: x.split(','))
///    .filter(lambda x: inside(x, goldman))
///    .map(lambda x: (get_hour(x[2]), 1))
///    .reduceByKey(add, 30)
///    .collect()
/// ```
fn q1_lineage(sc: &FlintContext) -> Rdd {
    sc.text_file(INPUT_BUCKET, "trips/")
        .map(|line| {
            // "x.split(',')" — parse the record; keep it as a value.
            let text = line.as_str().expect("text input").to_string();
            match TripRecord::parse_csv(text.as_bytes()) {
                Some(r) => Value::List(vec![
                    Value::F64(r.dropoff_lon as f64),
                    Value::F64(r.dropoff_lat as f64),
                    Value::I64(flint::data::chrono::hour_of_day(r.dropoff_ts) as i64),
                ]),
                None => Value::Null,
            }
        })
        .filter(|v| {
            // "inside(x, goldman)"
            let Value::List(fields) = v else { return false };
            let (Some(lon), Some(lat)) = (fields[0].as_f64(), fields[1].as_f64()) else {
                return false;
            };
            GOLDMAN.contains(lon as f32, lat as f32)
        })
        .map(|v| {
            // "(get_hour(x[2]), 1)"
            let Value::List(fields) = v else { unreachable!() };
            Value::pair(fields[2].clone(), Value::I64(1))
        })
        .reduce_by_key(30, |a, b| {
            Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
        })
}

/// Ground truth for the generic Q1 via the kernel oracle.
fn q1_expected(env: &SimEnv, ds: &Dataset) -> Vec<(i64, i64)> {
    use flint::compute::oracle;
    use flint::compute::queries::{QueryId, QueryResult};
    let QueryResult::Buckets(rows) = oracle::evaluate(env, ds, QueryId::Q1) else {
        panic!()
    };
    rows.into_iter().map(|(k, _, c)| (k, c as i64)).collect()
}

fn collected_to_rows(values: Vec<Value>) -> Vec<(i64, i64)> {
    let mut rows: Vec<(i64, i64)> = values
        .into_iter()
        .map(|v| (v.key().as_i64().unwrap(), v.val().as_i64().unwrap()))
        .collect();
    rows.sort();
    rows
}

#[test]
fn generic_q1_matches_kernel_oracle_on_flint() {
    let (env, ds) = setup();
    let sc = FlintContext::new(env.clone());
    let values = q1_lineage(&sc).collect().unwrap();
    assert_eq!(collected_to_rows(values), q1_expected(&env, &ds));
}

#[test]
fn generic_q1_matches_on_cluster_engines() {
    let (env, ds) = setup();
    let expect = q1_expected(&env, &ds);
    // The cluster contexts run the SAME lineage (built unbound, executed
    // per context) — the cross-engine check the session API is for.
    let sc = FlintContext::new(env.clone());
    let lineage = q1_lineage(&sc);
    for mode in [ClusterMode::Spark, ClusterMode::PySpark] {
        let cluster = FlintContext::cluster(env.clone(), mode);
        let values = cluster.collect(&lineage).unwrap();
        assert_eq!(collected_to_rows(values), expect, "{mode:?}");
        let report = cluster.run(&lineage, Action::Collect).unwrap();
        assert!(report.latency_s > 0.0);
        assert_eq!(report.stage_latencies.len(), 2, "{mode:?}");
    }
}

#[test]
fn generic_count_take_and_reduce_actions() {
    let (env, _ds) = setup();
    let sc = FlintContext::new(env.clone());
    let rdd = sc.text_file(INPUT_BUCKET, "trips/").filter(|v| {
        // keep lines ending in an even digit — arbitrary user predicate
        v.as_str().map(|s| s.as_bytes().last().map(|b| b % 2 == 0).unwrap_or(false))
            .unwrap_or(false)
    });
    let n = rdd.count().unwrap();
    assert!(n > 0 && n < TRIPS, "filter kept a strict subset: {n}");

    // take: a prefix of the deterministic collect order.
    let lens = sc
        .text_file(INPUT_BUCKET, "trips/")
        .map(|v| Value::I64(v.as_str().map(|s| s.len() as i64).unwrap_or(0)));
    let four = lens.take(4).unwrap();
    assert_eq!(four.len(), 4);
    let all = lens.collect().unwrap();
    assert_eq!(&all[..4], &four[..], "take is a prefix of collect");

    // reduce: fold at the driver.
    let total = lens
        .reduce(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
        .unwrap()
        .expect("non-empty");
    let expect: i64 = all.iter().map(|v| v.as_i64().unwrap()).sum();
    assert_eq!(total.as_i64().unwrap(), expect);
}

#[test]
fn generic_flatmap_word_count_style() {
    let (env, _ds) = setup();
    let sc = FlintContext::new(env);
    // Token count over the CSV: flatMap(split commas) -> (token_len, 1)
    // -> reduceByKey. A classic shape the engine must support.
    let rdd = sc
        .text_file(INPUT_BUCKET, "trips/")
        .flat_map(|v| {
            v.as_str()
                .map(|s| {
                    s.split(',')
                        .map(|t| Value::pair(Value::I64(t.len() as i64), Value::I64(1)))
                        .collect()
                })
                .unwrap_or_default()
        })
        .reduce_by_key(8, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
    let values = rdd.collect().unwrap();
    let total: i64 = values.iter().map(|v| v.val().as_i64().unwrap()).sum();
    assert_eq!(
        total as u64,
        TRIPS * flint::data::schema::NUM_COLUMNS as u64,
        "every field of every row tokenized exactly once"
    );
}

#[test]
fn generic_save_as_text_file() {
    let (env, _ds) = setup();
    let sc = FlintContext::new(env.clone());
    let rdd = sc
        .text_file(INPUT_BUCKET, "trips/")
        .map(|v| {
            Value::pair(
                Value::I64(v.as_str().map(|s| s.len() as i64).unwrap_or(0) % 7),
                Value::I64(1),
            )
        })
        .reduce_by_key(4, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
    let objects = rdd.save_as_text_file(OUTPUT_BUCKET, "lenmod7").unwrap();
    assert_eq!(objects, 4, "one output object per reduce partition");
    let listed = env.s3().list(OUTPUT_BUCKET, "lenmod7/").unwrap();
    assert_eq!(listed.len(), 4);
    let total_bytes: u64 = listed.iter().map(|(_, s)| s).sum();
    assert!(total_bytes > 0);
}

#[test]
fn generic_path_under_duplicates_and_failures() {
    let mut c = cfg();
    c.sim.sqs_duplicate_prob = 0.2;
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    env.failure().force_task_failure(0, 0, 0);
    let sc = FlintContext::new(env.clone());
    let values = q1_lineage(&sc).collect().unwrap();
    assert_eq!(collected_to_rows(values), q1_expected(&env, &ds));
}

// ---------------------------------------------------------------------
// Shapes the old per-shape planner could not express, held to the
// interpreter oracle on all three backends under both schedulers.
// ---------------------------------------------------------------------

/// Small deterministic text sources written straight into simulated S3.
fn seed_sources(env: &SimEnv) -> impl Fn(&str, &str) -> Vec<String> {
    env.s3().create_bucket(INPUT_BUCKET);
    for (prefix, objects) in source_data() {
        for (i, lines) in objects.iter().enumerate() {
            let body = format!("{}\n", lines.join("\n"));
            env.s3()
                .put_object(INPUT_BUCKET, &format!("{prefix}part-{i}"), body.into_bytes())
                .unwrap();
        }
    }
    |_: &str, prefix: &str| {
        source_data()
            .into_iter()
            .find(|(p, _)| *p == prefix)
            .map(|(_, objects)| objects.concat())
            .unwrap_or_default()
    }
}

fn source_data() -> Vec<(&'static str, Vec<Vec<String>>)> {
    let mk = |n: usize, salt: u64| -> Vec<String> {
        (0..n)
            .map(|i| "x".repeat(1 + ((i as u64 * 7 + salt) % 23) as usize))
            .collect()
    };
    vec![
        ("ga/", vec![mk(40, 1), mk(37, 5)]),
        ("gb/", vec![mk(29, 3)]),
    ]
}

fn pairify(rdd: &Rdd) -> Rdd {
    rdd.map(|v| {
        let len = v.as_str().map(|s| s.len() as i64).unwrap_or(0);
        Value::pair(Value::I64(len % 6), Value::I64(len))
    })
}

fn add() -> impl Fn(Value, Value) -> Value + Send + Sync + Clone {
    |a: Value, b: Value| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
}

/// reduceByKey *downstream* of a cogroup — the lineage that used to
/// panic "not supported yet" at the old `cogroup_shape`.
fn reduce_after_cogroup_lineage(a: Rdd, b: Rdd) -> Rdd {
    pairify(&a)
        .cogroup(&pairify(&b), 4)
        .flat_map(|v| {
            // score each (key, [left, right]) and re-key by score % 3,
            // so the cogroup feeds a further shuffle.
            let Value::List(sides) = v.val() else { return Vec::new() };
            let sum = |side: &Value| -> i64 {
                let Value::List(vals) = side else { return 0 };
                vals.iter().filter_map(Value::as_i64).sum()
            };
            let score = sum(&sides[0]) * 31 + sum(&sides[1]);
            vec![Value::pair(Value::I64(score % 3), Value::I64(score))]
        })
        .reduce_by_key(2, add())
}

/// A diamond over a shared sub-lineage: `base` feeds two different
/// reduces whose results join — the compiler must plan `base` once.
fn shared_diamond_lineage(src: Rdd) -> Rdd {
    let base = pairify(&src);
    let sums = base.reduce_by_key(4, add());
    let maxes = base.reduce_by_key(4, |a, b| {
        Value::I64(a.as_i64().unwrap().max(b.as_i64().unwrap()))
    });
    sums.join(&maxes, 3)
}

/// Run `lineage` on every backend/scheduler combination and compare the
/// collected values against the interpreter oracle, exactly.
fn assert_matches_oracle_everywhere(
    lineage_of: impl Fn(&FlintContext) -> Rdd,
    expect_of: impl Fn(&dyn Fn(&str, &str) -> Vec<String>) -> Vec<Value>,
) {
    // Flint engine: sqs and s3 backends, barrier and pipelined.
    for backend in [ShuffleBackend::Sqs, ShuffleBackend::S3] {
        for sched in [ScheduleMode::Barrier, ScheduleMode::Pipelined] {
            let mut c = cfg();
            c.flint.shuffle_backend = backend;
            c.flint.scheduler = sched;
            c.sim.sqs_duplicate_prob = 0.15;
            let env = SimEnv::new(c);
            let lines = seed_sources(&env);
            let sc = FlintContext::new(env.clone());
            let got = lineage_of(&sc).collect().unwrap();
            assert_eq!(got, expect_of(&lines), "{backend:?}/{sched:?}");
            if backend == ShuffleBackend::Sqs {
                assert_eq!(env.sqs().queue_names().len(), 0, "edge queues torn down");
            }
        }
    }
    // Memory backend: the cluster context (barrier), plus the same plan
    // under the pipelined clock straight through the driver.
    let env = SimEnv::new(cfg());
    let lines = seed_sources(&env);
    let cluster = FlintContext::cluster(env.clone(), ClusterMode::Spark);
    let lineage = lineage_of(&cluster);
    let got = lineage.collect().unwrap();
    let expect = expect_of(&lines);
    assert_eq!(got, expect, "memory/barrier");
    let plan = cluster.lower(&lineage, Action::Collect);
    let params = RunParams {
        mode: IoMode::Spark,
        transport: Transport::Memory(MemoryShuffle::new()),
        slots: 16,
        lambda: false,
        host_parallelism: 4,
        schedule: ScheduleMode::Pipelined,
        bill_idle: true,
        predictor: None,
    };
    let out = run_plan(&env, None, &plan, &params).unwrap();
    let ActionOut::Values(got) = out.out else { panic!("collect produced {:?}", out.out) };
    assert_eq!(got, expect, "memory/pipelined");
}

#[test]
fn reduce_by_key_after_cogroup_matches_oracle_on_all_backends() {
    assert_matches_oracle_everywhere(
        |sc| {
            reduce_after_cogroup_lineage(
                sc.text_file(INPUT_BUCKET, "ga/"),
                sc.text_file(INPUT_BUCKET, "gb/"),
            )
        },
        |lines| {
            let rdd = reduce_after_cogroup_lineage(
                Rdd::text_file(INPUT_BUCKET, "ga/"),
                Rdd::text_file(INPUT_BUCKET, "gb/"),
            );
            interp::interpret(&rdd, lines)
        },
    );
}

#[test]
fn shared_sublineage_diamond_matches_oracle_on_all_backends() {
    assert_matches_oracle_everywhere(
        |sc| shared_diamond_lineage(sc.text_file(INPUT_BUCKET, "ga/")),
        |lines| {
            let rdd = shared_diamond_lineage(Rdd::text_file(INPUT_BUCKET, "ga/"));
            interp::interpret(&rdd, lines)
        },
    );
}

#[test]
fn shared_diamond_scans_the_base_once_and_fans_out() {
    let env = SimEnv::new(cfg());
    seed_sources(&env);
    let sc = FlintContext::new(env.clone());
    let lineage = shared_diamond_lineage(sc.text_file(INPUT_BUCKET, "ga/"));
    let plan = sc.lower(&lineage, Action::Collect);
    assert_eq!(plan.stages.len(), 4, "scan, two reduces, join:\n{}", plan.explain());
    assert_eq!(plan.children(0), vec![1, 2], "one scan stage, two shuffle edges");
    let report = sc.run(&lineage, Action::Collect).unwrap();
    let edges: Vec<(u32, u32)> = report.edge_shuffle.iter().map(|e| (e.from, e.to)).collect();
    assert_eq!(edges, vec![(0, 1), (0, 2), (1, 3), (2, 3)], "{:?}", report.edge_shuffle);
    assert!(report.edge_shuffle.iter().all(|e| e.msgs > 0), "every edge carried data");
    assert_eq!(env.sqs().queue_names().len(), 0, "per-edge queues all torn down");
}

#[test]
fn outer_joins_match_oracle_and_pad_with_null() {
    let env = SimEnv::new(cfg());
    let lines = seed_sources(&env);
    let sc = FlintContext::new(env.clone());
    // Shrink each side's key space differently (left: odd and key 5;
    // right: even) so every variant has matched AND unmatched keys.
    let left_of = |src: Rdd| {
        pairify(&src).filter(|v| v.key().as_i64().map(|k| k != 0).unwrap_or(false))
    };
    let right_of = |src: Rdd| {
        pairify(&src).filter(|v| v.key().as_i64().map(|k| k % 2 == 0).unwrap_or(false))
    };
    type JoinFn = fn(&Rdd, &Rdd, usize) -> Rdd;
    let variants: [(&str, JoinFn); 3] = [
        ("left", Rdd::left_outer_join),
        ("right", Rdd::right_outer_join),
        ("full", Rdd::full_outer_join),
    ];
    for (name, join) in variants {
        let bound = join(
            &left_of(sc.text_file(INPUT_BUCKET, "ga/")),
            &right_of(sc.text_file(INPUT_BUCKET, "gb/")),
            3,
        );
        let got = bound.collect().unwrap();
        let unbound = join(
            &left_of(Rdd::text_file(INPUT_BUCKET, "ga/")),
            &right_of(Rdd::text_file(INPUT_BUCKET, "gb/")),
            3,
        );
        assert_eq!(got, interp::interpret(&unbound, &lines), "{name} outer join");
        let nulls = got
            .iter()
            .filter(|v| {
                let pair = v.val();
                matches!(pair.key(), Value::Null) || matches!(pair.val(), Value::Null)
            })
            .count();
        assert!(nulls > 0, "{name} outer join padded at least one unmatched side");
    }
    // Inner join never pads.
    let inner = left_of(sc.text_file(INPUT_BUCKET, "ga/")).join(
        &right_of(sc.text_file(INPUT_BUCKET, "gb/")),
        3,
    );
    let got = inner.collect().unwrap();
    assert!(got.iter().all(|v| {
        !matches!(v.val().key(), Value::Null) && !matches!(v.val().val(), Value::Null)
    }));
}

#[test]
fn long_op_chain_trips_the_payload_limit_spill_path() {
    // Per-op-kind code accounting: each map adds ~1.8 KB of "pickled
    // closure" to the task payload, so a long enough chain crosses the
    // Lambda payload limit and the scheduler must stage the task state
    // through S3 (the §III-B payload-split workaround). Tightened limit
    // keeps the test fast; the machinery is the same at 6 MB.
    let mut c = cfg();
    c.sim.lambda_payload_limit_bytes = 96 * 1024;
    let env = SimEnv::new(c);
    let lines = seed_sources(&env);
    let sc = FlintContext::new(env.clone());

    let mut short = pairify(&sc.text_file(INPUT_BUCKET, "gb/"));
    let mut long = pairify(&sc.text_file(INPUT_BUCKET, "gb/"));
    let mut oracle = pairify(&Rdd::text_file(INPUT_BUCKET, "gb/"));
    for _ in 0..64 {
        long = long.map(|v| v);
        oracle = oracle.map(|v| v);
    }
    short = short.map(|v| v);

    assert!(short.collect().is_ok());
    assert_eq!(env.metrics().get("scheduler.payload_spills"), 0, "short chain fits inline");

    let got = long.collect().unwrap();
    assert!(
        env.metrics().get("scheduler.payload_spills") > 0,
        "64 maps x ~1.8KB must exceed the 96KB limit and spill via S3"
    );
    assert_eq!(got, interp::interpret(&oracle, &lines), "spilled tasks still run correctly");
}
