//! The generic RDD path: arbitrary map/filter/flatMap/reduceByKey
//! lineages over dynamic values — "Flint is a Spark execution engine, it
//! supports arbitrary RDD transformations" (§V). The Q1 driver program
//! from the paper's §IV is reproduced verbatim in structure here.

use flint::compute::value::Value;
use flint::config::FlintConfig;
use flint::data::schema::{TripRecord, GOLDMAN};
use flint::data::{generate_taxi_dataset, Dataset, INPUT_BUCKET, OUTPUT_BUCKET};
use flint::exec::{ClusterEngine, ClusterMode, FlintEngine};
use flint::plan::{Action, Rdd};
use flint::services::SimEnv;

const TRIPS: u64 = 15_000;

fn setup() -> (SimEnv, Dataset) {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 512 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false;
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    (env, ds)
}

/// The paper's Q1, written against the generic API:
/// ```python
/// src.map(lambda x: x.split(','))
///    .filter(lambda x: inside(x, goldman))
///    .map(lambda x: (get_hour(x[2]), 1))
///    .reduceByKey(add, 30)
///    .collect()
/// ```
fn q1_lineage() -> Rdd {
    Rdd::text_file(INPUT_BUCKET, "trips/")
        .map(|line| {
            // "x.split(',')" — parse the record; keep it as a value.
            let text = line.as_str().expect("text input").to_string();
            match TripRecord::parse_csv(text.as_bytes()) {
                Some(r) => Value::List(vec![
                    Value::F64(r.dropoff_lon as f64),
                    Value::F64(r.dropoff_lat as f64),
                    Value::I64(flint::data::chrono::hour_of_day(r.dropoff_ts) as i64),
                ]),
                None => Value::Null,
            }
        })
        .filter(|v| {
            // "inside(x, goldman)"
            let Value::List(fields) = v else { return false };
            let (Some(lon), Some(lat)) = (fields[0].as_f64(), fields[1].as_f64()) else {
                return false;
            };
            GOLDMAN.contains(lon as f32, lat as f32)
        })
        .map(|v| {
            // "(get_hour(x[2]), 1)"
            let Value::List(fields) = v else { unreachable!() };
            Value::pair(fields[2].clone(), Value::I64(1))
        })
        .reduce_by_key(30, |a, b| {
            Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
        })
}

/// Ground truth for the generic Q1 via the kernel oracle.
fn q1_expected(env: &SimEnv, ds: &Dataset) -> Vec<(i64, i64)> {
    use flint::compute::oracle;
    use flint::compute::queries::{QueryId, QueryResult};
    let QueryResult::Buckets(rows) = oracle::evaluate(env, ds, QueryId::Q1) else {
        panic!()
    };
    rows.into_iter().map(|(k, _, c)| (k, c as i64)).collect()
}

fn collected_to_rows(values: Vec<Value>) -> Vec<(i64, i64)> {
    let mut rows: Vec<(i64, i64)> = values
        .into_iter()
        .map(|v| (v.key().as_i64().unwrap(), v.val().as_i64().unwrap()))
        .collect();
    rows.sort();
    rows
}

#[test]
fn generic_q1_matches_kernel_oracle_on_flint() {
    let (env, ds) = setup();
    let flint = FlintEngine::new(env.clone());
    let values = flint::exec::flint::run_rdd_collect(&flint, &q1_lineage(), &ds).unwrap();
    assert_eq!(collected_to_rows(values), q1_expected(&env, &ds));
}

#[test]
fn generic_q1_matches_on_cluster_engines() {
    let (env, ds) = setup();
    let expect = q1_expected(&env, &ds);
    for mode in [ClusterMode::Spark, ClusterMode::PySpark] {
        let engine = ClusterEngine::new(env.clone(), mode);
        let report = engine.run_rdd(&q1_lineage(), Action::Collect, &ds).unwrap();
        // Cluster engines return via the report's generic path; re-collect
        // through Flint for typed values instead, so just check the run
        // completed with matching task structure.
        assert!(report.latency_s > 0.0);
        assert_eq!(report.stage_latencies.len(), 2, "{mode:?}");
    }
}

#[test]
fn generic_count_action() {
    let (env, ds) = setup();
    let flint = FlintEngine::new(env.clone());
    let rdd = Rdd::text_file(INPUT_BUCKET, "trips/").filter(|v| {
        // keep lines ending in an even digit — arbitrary user predicate
        v.as_str().map(|s| s.as_bytes().last().map(|b| b % 2 == 0).unwrap_or(false))
            .unwrap_or(false)
    });
    let report = flint.run_rdd(&rdd, Action::Count, &ds).unwrap();
    let flint::compute::queries::QueryResult::Count(n) = report.result else { panic!() };
    assert!(n > 0 && n < TRIPS, "filter kept a strict subset: {n}");
}

#[test]
fn generic_flatmap_word_count_style() {
    let (env, ds) = setup();
    let flint = FlintEngine::new(env.clone());
    // Token count over the CSV: flatMap(split commas) -> (token_len, 1)
    // -> reduceByKey. A classic shape the engine must support.
    let rdd = Rdd::text_file(INPUT_BUCKET, "trips/")
        .flat_map(|v| {
            v.as_str()
                .map(|s| {
                    s.split(',')
                        .map(|t| Value::pair(Value::I64(t.len() as i64), Value::I64(1)))
                        .collect()
                })
                .unwrap_or_default()
        })
        .reduce_by_key(8, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
    let values = flint::exec::flint::run_rdd_collect(&flint, &rdd, &ds).unwrap();
    let total: i64 = values.iter().map(|v| v.val().as_i64().unwrap()).sum();
    assert_eq!(
        total as u64,
        TRIPS * flint::data::schema::NUM_COLUMNS as u64,
        "every field of every row tokenized exactly once"
    );
}

#[test]
fn generic_save_as_text_file() {
    let (env, ds) = setup();
    let flint = FlintEngine::new(env.clone());
    let rdd = Rdd::text_file(INPUT_BUCKET, "trips/")
        .map(|v| Value::pair(Value::I64(v.as_str().map(|s| s.len() as i64).unwrap_or(0) % 7, ), Value::I64(1)))
        .reduce_by_key(4, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
    let report = flint
        .run_rdd(
            &rdd,
            Action::SaveAsText { bucket: OUTPUT_BUCKET.into(), prefix: "lenmod7".into() },
            &ds,
        )
        .unwrap();
    assert!(report.latency_s > 0.0);
    let listed = env.s3().list(OUTPUT_BUCKET, "lenmod7/").unwrap();
    assert_eq!(listed.len(), 4, "one output object per reduce partition");
    let total_bytes: u64 = listed.iter().map(|(_, s)| s).sum();
    assert!(total_bytes > 0);
}

#[test]
fn generic_path_under_duplicates_and_failures() {
    let (env, ds) = {
        let mut c = FlintConfig::for_tests();
        c.data.object_bytes = 512 * 1024;
        c.flint.input_split_bytes = 256 * 1024;
        c.flint.use_pjrt = false;
        c.sim.sqs_duplicate_prob = 0.2;
        let env = SimEnv::new(c);
        let ds = generate_taxi_dataset(&env, "trips", TRIPS);
        (env, ds)
    };
    env.failure().force_task_failure(0, 0, 0);
    let flint = FlintEngine::new(env.clone());
    let values = flint::exec::flint::run_rdd_collect(&flint, &q1_lineage(), &ds).unwrap();
    assert_eq!(collected_to_rows(values), q1_expected(&env, &ds));
}
