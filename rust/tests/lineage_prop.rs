//! Randomized-lineage property test: generate arbitrary operator trees
//! over the generic API — mixes of map/filter/flatMap/reduceByKey/
//! cogroup, with shared sub-lineages and self-cogroups — execute them
//! through `FlintContext` on every shuffle backend (sqs, s3, memory)
//! under both schedulers, and require the collected values to equal the
//! single-threaded interpreter oracle (`plan::interp`) exactly.
//!
//! Every run executes with **speculation enabled and random heavy-tailed
//! stragglers injected**, so racing duplicate attempts (speculative
//! backups re-executing scans and reduces, re-sending byte-identical
//! shuffle streams, draining acked-empty partitions) continuously hammer
//! the attempt-safe commit machinery on every backend — on top of the
//! SQS duplicate injection that was already on. The per-edge
//! queue-lifecycle leak check still holds with backup attempts in play.
//!
//! This is the contract the `plan::lower` compiler is held to: there is
//! no lineage shape the planner special-cases, so there must be no
//! lineage shape the tests special-case either.

use flint::compute::value::Value;
use flint::config::{FlintConfig, ShuffleBackend, ShuffleExchange};
use flint::data::{INPUT_BUCKET, SHUFFLE_BUCKET};
use flint::exec::driver::{run_plan, ActionOut, RunParams};
use flint::exec::executor::IoMode;
use flint::exec::shuffle::{MemoryShuffle, Transport};
use flint::exec::{ClusterMode, FlintContext};
use flint::plan::rdd::RddNode;
use flint::plan::{interp, Action, Rdd, StorageLevel};
use flint::services::SimEnv;
use flint::simtime::ScheduleMode;
use flint::util::propcheck::{forall, Gen};

// -- deterministic sources --------------------------------------------

fn source_data() -> Vec<(&'static str, Vec<String>)> {
    let mk = |n: usize, salt: u64| -> Vec<String> {
        (0..n)
            .map(|i| "x".repeat(1 + ((i as u64 * 11 + salt) % 19) as usize))
            .collect()
    };
    vec![("pa/", mk(48, 2)), ("pb/", mk(33, 7))]
}

fn seed_sources(env: &SimEnv) {
    env.s3().create_bucket(INPUT_BUCKET);
    for (prefix, lines) in source_data() {
        // Two objects per source so scans have several splits/tasks.
        let mid = lines.len() / 2;
        for (i, chunk) in [&lines[..mid], &lines[mid..]].iter().enumerate() {
            let body = format!("{}\n", chunk.join("\n"));
            env.s3()
                .put_object(INPUT_BUCKET, &format!("{prefix}part-{i}"), body.into_bytes())
                .unwrap();
        }
    }
}

fn oracle_lines(_bucket: &str, prefix: &str) -> Vec<String> {
    source_data()
        .into_iter()
        .find(|(p, _)| *p == prefix)
        .map(|(_, lines)| lines)
        .unwrap_or_default()
}

// -- lineage generator ------------------------------------------------

/// Every generated lineage emits `(I64 key, I64 value)` pairs with keys
/// in 0..7 and bounded values, so any node can legally feed any wide op.
/// `cache_prob` sprinkles random `cache()`/`persist(...)` markers over
/// generated nodes (0.0 = the original marker-free generator); pool
/// reuse then shares *cached* sub-lineages across diamonds too.
fn gen_lineage(g: &mut Gen, wide_budget: &mut usize, pool: &mut Vec<Rdd>, cache_prob: f64) -> Rdd {
    // Reuse an already-built subtree sometimes: the shared-sublineage /
    // diamond path (same Arc node consumed twice).
    if !pool.is_empty() && g.chance(0.25) {
        return pool[g.usize(pool.len())].clone();
    }
    let rdd = if *wide_budget == 0 || g.chance(0.3) {
        gen_base(g)
    } else {
        *wide_budget -= 1;
        if g.bool() {
            let parts = g.usize(4) + 1;
            let child = gen_narrowed(g, wide_budget, pool, cache_prob);
            gen_reduce(g, &child, parts)
        } else {
            let parts = g.usize(4) + 1;
            let left = gen_narrowed(g, wide_budget, pool, cache_prob);
            // Self-cogroup sometimes: both sides the same handle.
            let right = if g.chance(0.2) {
                left.clone()
            } else {
                gen_narrowed(g, wide_budget, pool, cache_prob)
            };
            cogroup_flatten(&left, &right, parts)
        }
    };
    let rdd = if cache_prob > 0.0 && g.chance(cache_prob) {
        match g.usize(3) {
            0 => rdd.cache(),
            1 => rdd.persist(StorageLevel::Memory),
            _ => rdd.persist(StorageLevel::S3),
        }
    } else {
        rdd
    };
    pool.push(rdd.clone());
    rdd
}

/// A child lineage with 0..2 extra narrow ops on top.
fn gen_narrowed(g: &mut Gen, wide_budget: &mut usize, pool: &mut Vec<Rdd>, cache_prob: f64) -> Rdd {
    let mut rdd = gen_lineage(g, wide_budget, pool, cache_prob);
    for _ in 0..g.usize(3) {
        rdd = gen_narrow(g, &rdd);
    }
    rdd
}

fn gen_base(g: &mut Gen) -> Rdd {
    let prefix = if g.bool() { "pa/" } else { "pb/" };
    let keymod = [5i64, 6, 7][g.usize(3)];
    Rdd::text_file(INPUT_BUCKET, prefix).map(move |v| {
        let len = v.as_str().map(|s| s.len() as i64).unwrap_or(0);
        Value::pair(Value::I64(len % keymod), Value::I64(len))
    })
}

fn gen_narrow(g: &mut Gen, rdd: &Rdd) -> Rdd {
    match g.usize(4) {
        0 => rdd.map(|v| {
            let (k, val) = (v.key().as_i64().unwrap(), v.val().as_i64().unwrap());
            Value::pair(Value::I64((k * 3 + 1).rem_euclid(7)), Value::I64(val))
        }),
        1 => rdd.map(|v| {
            let (k, val) = (v.key().as_i64().unwrap(), v.val().as_i64().unwrap());
            Value::pair(Value::I64(k), Value::I64((val * 5 + 1) % 1009))
        }),
        2 => rdd.filter(|v| v.val().as_i64().map(|x| x % 3 != 0).unwrap_or(false)),
        _ => rdd.flat_map(|v| {
            let (k, val) = (v.key().as_i64().unwrap(), v.val().as_i64().unwrap());
            vec![
                Value::pair(Value::I64(k), Value::I64(val)),
                Value::pair(Value::I64((k + 1).rem_euclid(7)), Value::I64(val % 97)),
            ]
        }),
    }
}

fn gen_reduce(g: &mut Gen, rdd: &Rdd, parts: usize) -> Rdd {
    // Associative + commutative combiners only: the engine folds in
    // arrival order, the oracle in its own order — anything else is a
    // misuse of reduceByKey, in Spark too.
    match g.usize(3) {
        0 => rdd.reduce_by_key(parts, |a, b| {
            Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
        }),
        1 => rdd.reduce_by_key(parts, |a, b| {
            Value::I64(a.as_i64().unwrap().min(b.as_i64().unwrap()))
        }),
        _ => rdd.reduce_by_key(parts, |a, b| {
            Value::I64(a.as_i64().unwrap().max(b.as_i64().unwrap()))
        }),
    }
}

/// Cogroup and flatten straight back to `(key, score)` pairs so the
/// result composes with further ops. The score only uses per-side sums
/// and lengths — order-insensitive, since side order is only
/// deterministic after sorting.
fn cogroup_flatten(left: &Rdd, right: &Rdd, parts: usize) -> Rdd {
    left.cogroup(right, parts).flat_map(|v| {
        let key = v.key().clone();
        let Value::List(sides) = v.val() else { return Vec::new() };
        let stat = |side: &Value| -> (i64, i64) {
            let Value::List(vals) = side else { return (0, 0) };
            (vals.iter().filter_map(Value::as_i64).sum(), vals.len() as i64)
        };
        let (ls, ln) = stat(&sides[0]);
        let (rs, rn) = stat(&sides[1]);
        vec![Value::pair(key, Value::I64(ls * 31 + rs + ln * 7 + rn))]
    })
}

// -- execution matrix -------------------------------------------------

fn base_cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.flint.input_split_bytes = 256;
    c.flint.use_pjrt = false;
    c.sim.sqs_duplicate_prob = 0.1;
    // Racing duplicate attempts everywhere: random stragglers draw
    // speculative backups (aggressive policy so the tail signal fires
    // often even on small stages), and the oracle equality below proves
    // the races can never change an answer.
    c.flint.speculation.enabled = true;
    c.flint.speculation.multiplier = 1.2;
    c.flint.speculation.quantile = 0.5;
    c.sim.straggler_prob = 0.2;
    c.sim.straggler_factor = 5.0;
    c
}

/// One (backend, scheduler, exchange) execution of an unbound lineage.
fn run_config(
    rdd: &Rdd,
    backend: ShuffleBackend,
    sched: ScheduleMode,
    exchange: ShuffleExchange,
) -> Result<Vec<Value>, String> {
    let mut c = base_cfg();
    c.flint.shuffle_backend = backend;
    c.flint.scheduler = sched;
    c.flint.shuffle_exchange = exchange;
    if exchange == ShuffleExchange::Tree {
        // Minimum threshold: even these small stages go through the
        // merge level, so speculative backups race tree group objects
        // and merge-task commits, not just direct partition writes.
        c.flint.tree_fanout = 2;
    }
    let env = SimEnv::new(c);
    seed_sources(&env);
    let sc = FlintContext::new(env.clone());
    let got = sc
        .collect(rdd)
        .map_err(|e| format!("{backend:?}/{sched:?}/{exchange:?}: {e:#}"))?;
    if backend == ShuffleBackend::Sqs && !env.sqs().queue_names().is_empty() {
        return Err(format!("{backend:?}/{sched:?}: leaked edge queues"));
    }
    if backend == ShuffleBackend::S3 {
        // Per-edge prefix teardown must sweep every shuffle object —
        // committed partitions, tree group objects, merge outputs, and
        // crashed/losing attempts' temps alike.
        let left = env.s3().list(SHUFFLE_BUCKET, "").unwrap_or_default();
        if !left.is_empty() {
            return Err(format!(
                "{backend:?}/{sched:?}/{exchange:?}: {} leaked shuffle objects: {:?}",
                left.len(),
                left.iter().take(5).collect::<Vec<_>>()
            ));
        }
    }
    Ok(got)
}

/// Memory backend: cluster context for barrier, the raw driver for the
/// pipelined clock (the cluster engine itself pins barrier).
fn run_memory(rdd: &Rdd, sched: ScheduleMode) -> Result<Vec<Value>, String> {
    let env = SimEnv::new(base_cfg());
    seed_sources(&env);
    let sc = FlintContext::cluster(env.clone(), ClusterMode::Spark);
    match sched {
        ScheduleMode::Barrier => sc.collect(rdd).map_err(|e| format!("memory/barrier: {e:#}")),
        ScheduleMode::Pipelined => {
            let plan = sc.lower(rdd, Action::Collect);
            let params = RunParams {
                mode: IoMode::Spark,
                transport: Transport::Memory(MemoryShuffle::new()),
                slots: 16,
                lambda: false,
                host_parallelism: 4,
                schedule: ScheduleMode::Pipelined,
                bill_idle: true,
                predictor: None,
            };
            let out = run_plan(&env, None, &plan, &params)
                .map_err(|e| format!("memory/pipelined: {e:#}"))?;
            match out.out {
                ActionOut::Values(v) => Ok(v),
                other => Err(format!("memory/pipelined collect produced {other:?}")),
            }
        }
    }
}

#[test]
fn prop_random_lineages_match_interpreter_oracle_on_all_backends() {
    forall("random-lineage-vs-oracle", 8, |g| {
        let mut wide_budget = 3;
        let mut pool = Vec::new();
        let rdd = gen_narrowed(g, &mut wide_budget, &mut pool, 0.0);
        let expect = interp::interpret(&rdd, &oracle_lines);

        for backend in [ShuffleBackend::Sqs, ShuffleBackend::S3] {
            for sched in [ScheduleMode::Barrier, ScheduleMode::Pipelined] {
                let got = run_config(&rdd, backend, sched, ShuffleExchange::Direct)?;
                if got != expect {
                    return Err(format!(
                        "{backend:?}/{sched:?} diverged from oracle for {rdd:?}:\n\
                         got    {got:?}\nexpect {expect:?}"
                    ));
                }
            }
        }
        // The multi-level tree exchange under the same speculation +
        // straggler + duplicate injection: every S3 edge detours
        // through producer-group objects and a merge level, and the
        // answer still has to be bit-identical to the oracle.
        for sched in [ScheduleMode::Barrier, ScheduleMode::Pipelined] {
            let got = run_config(&rdd, ShuffleBackend::S3, sched, ShuffleExchange::Tree)?;
            if got != expect {
                return Err(format!(
                    "s3-tree/{sched:?} diverged from oracle for {rdd:?}:\n\
                     got    {got:?}\nexpect {expect:?}"
                ));
            }
        }
        for sched in [ScheduleMode::Barrier, ScheduleMode::Pipelined] {
            let got = run_memory(&rdd, sched)?;
            if got != expect {
                return Err(format!(
                    "memory/{sched:?} diverged from oracle for {rdd:?}:\n\
                     got    {got:?}\nexpect {expect:?}"
                ));
            }
        }

        // The count action agrees with the oracle's record count (one
        // backend suffices; counting shares the whole pipeline).
        let env = SimEnv::new(base_cfg());
        seed_sources(&env);
        let sc = FlintContext::new(env);
        let n = sc.count(&rdd).map_err(|e| format!("count: {e:#}"))?;
        if n != interp::interpret_count(&rdd, &oracle_lines) {
            return Err(format!("count action diverged: {n}"));
        }
        Ok(())
    });
}

/// Distinct `Cached` markers in a lineage (diamonds counted once).
fn count_markers(rdd: &Rdd, seen: &mut std::collections::HashSet<usize>) -> usize {
    if !seen.insert(flint::plan::CacheResolution::node_key(rdd)) {
        return 0;
    }
    match &*rdd.node {
        RddNode::TextFile { .. } => 0,
        RddNode::Narrow { parent, .. } | RddNode::ReduceByKey { parent, .. } => {
            count_markers(parent, seen)
        }
        RddNode::CoGroup { left, right, .. } => {
            count_markers(left, seen) + count_markers(right, seen)
        }
        RddNode::Cached { parent, .. } => 1 + count_markers(parent, seen),
    }
}

/// Cache transparency under the full adversarial setup: random lineages
/// with random `cache()`/`persist(...)` placements (shared sub-lineages
/// and diamonds included) run **twice through one session** — with
/// speculation, stragglers, and duplicate injection still on. Both runs
/// must equal the interpreter oracle bit-exactly (the oracle never sees
/// the markers — `interp` treats them as transparent), and the re-run
/// must report at least one registry hit: every marker's fingerprint is
/// stable across runs of the same handles, and capacity is ample.
#[test]
fn prop_cached_lineages_match_oracle_and_hit_on_rerun() {
    forall("cached-lineage-vs-oracle", 8, |g| {
        let mut wide_budget = 3;
        let mut pool = Vec::new();
        let mut rdd = gen_narrowed(g, &mut wide_budget, &mut pool, 0.35);
        if count_markers(&rdd, &mut std::collections::HashSet::new()) == 0 {
            rdd = rdd.cache();
        }
        let expect = interp::interpret(&rdd, &oracle_lines);

        let mut c = base_cfg();
        c.flint.cache.capacity_bytes = 1 << 30;
        let env = SimEnv::new(c);
        seed_sources(&env);
        let sc = FlintContext::new(env.clone());

        let cold = sc.collect(&rdd).map_err(|e| format!("cached cold run: {e:#}"))?;
        if cold != expect {
            return Err(format!(
                "cached cold run diverged from oracle for {rdd:?}:\n\
                 got    {cold:?}\nexpect {expect:?}"
            ));
        }
        if env.metrics().get("cache.builds") == 0 {
            return Err("cold run built no cache entries".to_string());
        }
        let hits_before = env.metrics().get("cache.hits");
        let warm = sc.collect(&rdd).map_err(|e| format!("cached warm run: {e:#}"))?;
        if warm != expect {
            return Err(format!(
                "cached warm run diverged from oracle for {rdd:?}:\n\
                 got    {warm:?}\nexpect {expect:?}"
            ));
        }
        if env.metrics().get("cache.hits") == hits_before {
            return Err("warm re-run reported no cache hits".to_string());
        }
        Ok(())
    });
}
