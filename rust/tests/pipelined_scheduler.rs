//! The pipelined DAG scheduler (§III-A): reduce tasks long-poll their
//! SQS queues while map tasks still flush, so a consumer stage overlaps
//! its producers on the virtual clock. These tests pin the three load-
//! bearing properties of the refactor:
//!
//! 1. barrier mode reproduces the pre-DAG Σ-makespan latencies exactly
//!    (Table I stability),
//! 2. pipelined mode is *strictly* faster than barrier mode for every
//!    multi-stage Table I query on the SQS backend — measured from the
//!    same execution, so the comparison is exact, not cross-run noise,
//! 3. multi-parent plans (union/cogroup shape) execute end-to-end,
//!    clean up their queues via the per-edge refcounts, and report
//!    per-edge shuffle stats.

use flint::compute::oracle;
use flint::compute::queries::QueryId;
use flint::compute::value::Value;
use flint::config::FlintConfig;
use flint::data::{generate_taxi_dataset, Dataset};
use flint::exec::driver::{run_plan, ActionOut, RunParams};
use flint::exec::executor::IoMode;
use flint::exec::shuffle::Transport;
use flint::exec::{Engine, FlintEngine};
use flint::plan::{build_union_plan, dag, Action, DynOp, UnionBranch};
use flint::services::SimEnv;
use flint::simtime::ScheduleMode;
use std::sync::Arc;

const TRIPS: u64 = 30_000;

fn cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 512 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false;
    c
}

fn setup(c: FlintConfig) -> (SimEnv, Dataset) {
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    (env, ds)
}

/// The multi-stage Table I queries (everything but map-only Q0).
const MULTI_STAGE: [QueryId; 6] = [
    QueryId::Q1,
    QueryId::Q2,
    QueryId::Q3,
    QueryId::Q4,
    QueryId::Q5,
    QueryId::Q6,
];

#[test]
fn pipelined_strictly_beats_barrier_on_multistage_sqs_queries() {
    let (env, ds) = setup(cfg());
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    for q in MULTI_STAGE {
        let report = flint.run_query(q, &ds).unwrap();
        assert!(report.stage_latencies.len() >= 2, "{q} is multi-stage");
        // Both clocks come from the same run's measured task durations.
        assert!(
            report.pipelined_latency_s < report.barrier_latency_s,
            "{q}: pipelined {:.4}s must strictly beat barrier {:.4}s",
            report.pipelined_latency_s,
            report.barrier_latency_s
        );
        // Correctness is schedule-independent.
        let expect = oracle::evaluate(&env, &ds, q);
        assert!(report.result.approx_eq(&expect), "{q}: wrong result");
    }
}

#[test]
fn barrier_mode_reproduces_sigma_makespan_model() {
    // The SQS default flipped to pipelined with the Table I re-baseline;
    // `flint.scheduler = barrier` stays the exact-paper-reproduction
    // mode, and this test pins that the old Σ-makespan numbers still
    // hold under it.
    let mut c = cfg();
    c.flint.scheduler = ScheduleMode::Barrier;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q0, QueryId::Q1, QueryId::Q5] {
        let report = flint.run_query(q, &ds).unwrap();
        // Barrier selected: the headline latency IS the barrier clock...
        assert_eq!(report.latency_s, report.barrier_latency_s, "{q}");
        // ...and the barrier clock is exactly the seed's Σ(stage
        // makespan + overhead) model.
        let sigma: f64 = report.stage_latencies.iter().sum();
        assert!(
            (report.barrier_latency_s - sigma).abs() < 1e-6,
            "{q}: barrier {:.9}s vs Σ stage latencies {:.9}s",
            report.barrier_latency_s,
            sigma
        );
        // Barrier windows are serial and contiguous.
        for w in report.barrier_windows.windows(2) {
            assert!(
                (w[0].end - w[1].start).abs() < 1e-9,
                "{q}: barrier stages must not overlap"
            );
        }
    }
}

#[test]
fn pipelined_config_flag_selects_overlapping_clock() {
    let mut c = cfg();
    c.flint.scheduler = ScheduleMode::Pipelined;
    // Small driver overheads so the reduce stage becomes ready while the
    // (short, test-sized) map stage is still running — at paper scale
    // map stages run minutes and dwarf the default 0.35 s overhead, but
    // a 30k-trip test map stage does not.
    c.sim.scheduler_overhead_per_stage_s = 0.01;
    c.sim.scheduler_overhead_per_task_s = 0.0005;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert_eq!(report.latency_s, report.pipelined_latency_s);
    // The reduce stage's window starts while the map stage still runs
    // (long-polling), i.e. before the map window closes...
    let map_w = &report.pipelined_windows[0];
    let red_w = &report.pipelined_windows[1];
    assert!(
        red_w.start < map_w.end,
        "reduce window [{:.3}, {:.3}] must open inside map window [{:.3}, {:.3}]",
        red_w.start,
        red_w.end,
        map_w.start,
        map_w.end
    );
    // ...but no reduce task can finish before the last map flush.
    for (_, end) in &red_w.tasks {
        assert!(*end >= map_w.end - 1e-9, "reduce finished before its producers");
    }
    // Correct answer under the pipelined clock too.
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    assert!(report.result.approx_eq(&expect));
    // Queue lifecycle: per-edge refcounts tore everything down.
    assert_eq!(env.sqs().queue_names().len(), 0);
}

fn length_key_ops() -> Vec<DynOp> {
    vec![DynOp::Map(Arc::new(|v: Value| {
        let len = v.as_str().map(|s| s.len() as i64).unwrap_or(0);
        Value::pair(Value::I64(len % 7), Value::I64(1))
    }))]
}

#[test]
fn multi_parent_union_plan_executes_and_overlaps() {
    let c = cfg();
    let env = SimEnv::new(c.clone());
    let ds_a = generate_taxi_dataset(&env, "tripsa", 12_000);
    let ds_b = generate_taxi_dataset(&env, "tripsb", 8_000);
    env.s3().create_bucket(flint::data::SHUFFLE_BUCKET);
    env.s3().create_bucket(flint::data::OUTPUT_BUCKET);

    let combine: flint::plan::rdd::CombineFn =
        Arc::new(|a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
    let split_bytes = c.flint.input_split_bytes;
    let plan = build_union_plan(
        vec![
            UnionBranch { ops: length_key_ops(), splits: dag::input_splits(&ds_a, split_bytes) },
            UnionBranch { ops: length_key_ops(), splits: dag::input_splits(&ds_b, split_bytes) },
        ],
        4,
        combine,
        Vec::new(),
        Action::Collect,
    );
    assert_eq!(plan.stages.len(), 3);
    assert_eq!(plan.stages[2].parents, vec![0, 1], "reduce consumes both scans");
    plan.validate().unwrap();

    let params = RunParams {
        mode: IoMode::Flint,
        transport: Transport::Sqs,
        slots: env.config().sim.max_concurrency,
        lambda: true,
        host_parallelism: 4,
        schedule: ScheduleMode::Pipelined,
        bill_idle: true,
        predictor: None,
    };
    let out = run_plan(&env, None, &plan, &params).unwrap();

    // Every line of both datasets counted exactly once.
    let ActionOut::Values(values) = &out.out else {
        panic!("collect produced {:?}", out.out)
    };
    let total: i64 = values.iter().map(|v| v.val().as_i64().unwrap()).sum();
    assert_eq!(total, 12_000 + 8_000, "union counted every row of both datasets once");

    // The DAG actually fanned in: one shuffle edge per scan stage.
    assert_eq!(out.edge_shuffle.len(), 2, "{:?}", out.edge_shuffle);
    assert!(out.edge_shuffle.iter().any(|e| e.from == 0 && e.to == 2 && e.msgs > 0));
    assert!(out.edge_shuffle.iter().any(|e| e.from == 1 && e.to == 2 && e.msgs > 0));
    assert!(env.metrics().get("shuffle.edge.s0-s2.msgs") > 0);

    // Pipelined beats the fully-serial barrier by a wide margin here:
    // the two scans alone serialize under barrier but overlap under the
    // DAG clock.
    assert!(
        out.pipelined_latency_s < out.barrier_latency_s,
        "pipelined {:.4}s vs barrier {:.4}s",
        out.pipelined_latency_s,
        out.barrier_latency_s
    );
    assert_eq!(out.pipelined_windows.len(), 3);
    let scan_a = &out.pipelined_windows[0];
    let scan_b = &out.pipelined_windows[1];
    assert!(scan_b.overlap_s(scan_a) > 0.0, "independent scans must overlap");

    // Per-edge refcounted teardown: both producers' queues are gone.
    assert_eq!(env.sqs().queue_names().len(), 0, "queues must be refcount-deleted");
}

#[test]
fn pipelined_is_the_sqs_default_now() {
    // Satellite of the re-baseline: a default-config SQS run selects the
    // pipelined clock as its headline latency.
    let (env, ds) = setup(cfg());
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert_eq!(report.latency_s, report.pipelined_latency_s);
    // Speculation is off by default: the attempt model must leave the
    // schedule untouched (pipelined == pipelined-without-backups) and
    // launch nothing.
    assert_eq!(report.pipelined_latency_s, report.pipelined_nospec_latency_s);
    assert_eq!(report.speculative_launches, 0);
    assert_eq!(env.metrics().get("scheduler.speculative_launches"), 0);
}

#[test]
fn speculation_strictly_beats_plain_pipelined_under_stragglers() {
    // The acceptance criterion: with a heavy-tailed injected duration in
    // the scan stage, pipelined+speculation strictly reduces makespan vs
    // plain pipelined on EVERY multi-stage Table I query (plus the Q6J
    // join diamond) — both clocks measured from the same execution, and
    // results stay oracle-identical under the racing duplicate attempts.
    let mut c = cfg();
    c.flint.scheduler = ScheduleMode::Pipelined;
    c.flint.speculation.enabled = true;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let mut queries: Vec<QueryId> = MULTI_STAGE.to_vec();
    queries.push(QueryId::Q6J);
    for q in queries {
        // Re-arm a decisive straggler per run: scan task 1, primary
        // attempt only — the backup draws a clean container.
        env.failure().force_straggler(0, 1, 0, 10.0);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(
            report.speculative_launches >= 1,
            "{q}: the tail signal must fire for a 10x straggler"
        );
        assert!(
            report.speculative_wins >= 1,
            "{q}: the clean backup must win the race"
        );
        assert!(
            report.pipelined_latency_s < report.pipelined_nospec_latency_s,
            "{q}: speculation {:.4}s must strictly beat plain pipelined {:.4}s",
            report.pipelined_latency_s,
            report.pipelined_nospec_latency_s
        );
        let expect = oracle::evaluate(&env, &ds, q);
        assert!(
            report.result.approx_eq(&expect),
            "{q}: racing duplicate attempts changed the answer"
        );
    }
    assert!(env.metrics().get("scheduler.speculative_launches") >= 7);
    // Attempt-level queue lifecycle: backups drained/wrote real queues,
    // and every per-edge queue still tore down exactly once.
    assert_eq!(env.sqs().queue_names().len(), 0, "leaked shuffle queues");
}

#[test]
fn pipelined_idle_time_is_billed_as_gb_seconds() {
    // The ROADMAP's pipelined-aware cost item: long-polling reducers
    // occupy live Lambdas, so the overlap's latency win costs idle
    // GB-seconds. Same execution, both clocks: the pipelined run must
    // report (and bill) positive idle time, and the barrier-mode run of
    // the same query must not.
    let mut c = cfg();
    c.flint.scheduler = ScheduleMode::Pipelined;
    c.sim.scheduler_overhead_per_stage_s = 0.01;
    c.sim.scheduler_overhead_per_task_s = 0.0005;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert!(report.pipelined_idle_s > 0.0, "long-polling reducers must meter idle time");
    assert!(env.metrics().get("lambda.idle_billed_100ms") > 0, "idle must be billed");

    let mut c2 = cfg();
    c2.flint.scheduler = ScheduleMode::Barrier;
    let (env2, ds2) = setup(c2);
    let flint2 = FlintEngine::new(env2.clone());
    flint2.prewarm();
    let _ = flint2.run_query(QueryId::Q1, &ds2).unwrap();
    assert_eq!(
        env2.metrics().get("lambda.idle_billed_100ms"),
        0,
        "barrier mode has no long-polling idle to bill"
    );
}

#[test]
fn elasticity_pipelined_scales_with_slots() {
    // The pipelined clock must respect the shared concurrency limit:
    // fewer slots, more latency (same execution semantics as barrier).
    let mut lat = Vec::new();
    for slots in [2usize, 16] {
        let mut c = cfg();
        c.sim.max_concurrency = slots;
        c.flint.scheduler = ScheduleMode::Pipelined;
        let (env, ds) = setup(c);
        let flint = FlintEngine::new(env.clone());
        flint.prewarm();
        let report = flint.run_query(QueryId::Q1, &ds).unwrap();
        lat.push(report.latency_s);
    }
    assert!(
        lat[0] > lat[1],
        "2 slots ({:.3}s) must be slower than 16 ({:.3}s)",
        lat[0],
        lat[1]
    );
}
