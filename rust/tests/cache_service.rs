//! The lineage cache at the service layer, end to end:
//!
//! 1. **Cross-query reuse** — two tenants submitting the same lineage
//!    handles share one cache entry: the first query builds (and pays),
//!    the second hits without re-execution, results stay identical, and
//!    Σ per-tenant ledgers still equals the pool's billed spend to the
//!    last bit with builds and hits in play.
//! 2. **Hoisted scan cache** — a service LISTs (and stats-HEADs) a
//!    popular prefix once, not once per query: the second query's LIST
//!    count is zero.
//! 3. **Off means off** — with `flint.cache.capacity_bytes = 0` (the
//!    default), a lineage full of `cache()` markers produces a report
//!    and metrics registry byte-identical to the marker-free lineage in
//!    a fresh environment: the feature is invisible until switched on.

use flint::compute::value::Value;
use flint::config::FlintConfig;
use flint::data::{generate_taxi_dataset, INPUT_BUCKET};
use flint::exec::{FlintContext, FlintService};
use flint::plan::{Action, ActionOut, Rdd};
use flint::services::SimEnv;

/// Deterministic modeled config (no host-measured jitter).
fn modeled_cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.sim.compute_scale = 0.0;
    c
}

/// Scan → reduce over the taxi trips, with a `cache()` marker over the
/// scan when asked — the shared sub-lineage both tenants submit.
fn hour_pairs(sc: &FlintContext, cached: bool) -> Rdd {
    let scan = sc.text_file(INPUT_BUCKET, "trips/").map(|line| {
        let text = line.as_str().expect("text input");
        let hour = flint::data::schema::TripRecord::parse_csv(text.as_bytes())
            .map(|r| flint::data::chrono::hour_of_day(r.dropoff_ts) as i64)
            .unwrap_or(0);
        Value::pair(Value::I64(hour), Value::I64(1))
    });
    let scan = if cached { scan.cache() } else { scan };
    scan.reduce_by_key(8, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
}

#[test]
fn cross_tenant_cache_hit_keeps_ledgers_exact() {
    let mut cfg = modeled_cfg();
    cfg.flint.cache.capacity_bytes = 1 << 30;
    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let service = FlintService::new(env.clone());
    service.prewarm();

    // Both tenants submit the SAME lineage handles (shared op Arcs), so
    // the fingerprints agree and the registry can serve the re-use.
    let sc = service.session("acme");
    let rdd = hour_pairs(&sc, true);
    service.submit("acme", &rdd, Action::Collect).unwrap();
    service.submit("globex", &rdd, Action::Collect).unwrap();
    let report = service.run().unwrap();

    // The builder built, the second query hit — and never re-built.
    let m = env.metrics();
    assert!(m.get("q0.cache.builds") >= 1, "first query must build the entry");
    assert_eq!(m.get("q0.cache.hits"), 0);
    assert!(m.get("q1.cache.hits") >= 1, "second query must hit the registry");
    assert_eq!(m.get("q1.cache.builds"), 0, "a hit must not rebuild");
    assert!(service.shared().registry.len() >= 1);
    assert!(
        m.get("q0.cache.admitted_bytes") > 0,
        "admitted entries are metered in the builder's scope"
    );

    // Same answer for both queries.
    let rows = |out: &ActionOut| match out {
        ActionOut::Values(v) => v.clone(),
        other => panic!("expected values, got {other:?}"),
    };
    assert_eq!(rows(&report.queries[0].out), rows(&report.queries[1].out));

    // Billing stays exact with builds and hits in the windows: every
    // dollar is in exactly one query's diff, so Σ ledgers == pool spend.
    let ledger_sum: f64 = report.ledgers.values().map(|l| l.total_usd()).sum();
    assert!(
        (ledger_sum - report.run_cost.total()).abs() < 1e-15,
        "ledgers {ledger_sum} != pool {}",
        report.run_cost.total()
    );
    // The builder paid for the build; the hitter's truncated plan (a
    // cached scan instead of the full input scan + build) costs less.
    let acme = report.queries[0].cost.total();
    let globex = report.queries[1].cost.total();
    assert!(
        globex < acme,
        "cache hit must be cheaper than build: acme ${acme} vs globex ${globex}"
    );
}

#[test]
fn service_lists_a_popular_prefix_once() {
    let cfg = modeled_cfg();
    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let service = FlintService::new(env.clone());
    service.prewarm();
    let sc = service.session("acme");

    // Two queries over the same prefix — DIFFERENT lineages (fresh
    // closures), so nothing here rides the lineage cache; only the
    // hoisted scan cache can save the second LIST.
    service.submit("acme", &hour_pairs(&sc, false), Action::Collect).unwrap();
    let first = service.run().unwrap();
    assert_eq!(first.queries.len(), 1);
    let lists_after_first = env.metrics().get("s3.list");
    assert!(lists_after_first > 0, "the first query pays the LIST");

    service.submit("globex", &hour_pairs(&sc, false), Action::Collect).unwrap();
    service.run().unwrap();
    assert_eq!(
        env.metrics().get("s3.list"),
        lists_after_first,
        "the second query's LIST count must be zero (hoisted scan cache)"
    );
    assert!(env.metrics().get("q1.scan.list_cache_hits") >= 1);
}

#[test]
fn scan_resolution_never_goes_stale() {
    // Regression: the hoisted scan cache must not pin a prefix's first
    // resolution forever. A prefix read before its data exists, or read
    // back after the service itself wrote output under it, must see the
    // current objects — the cache invalidates on the bucket's write
    // generation and never caches empty listings.
    use flint::data::OUTPUT_BUCKET;
    let cfg = modeled_cfg();
    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let sc = FlintContext::new(env.clone());
    sc.prewarm();

    // Read the output prefix before anything lives there: empty, but
    // the empty resolution must not poison later reads.
    assert_eq!(sc.count(&sc.text_file(OUTPUT_BUCKET, "hist/")).unwrap(), 0);

    // The same engine writes output under that prefix...
    let saved = hour_pairs(&sc, false).save_as_text_file(OUTPUT_BUCKET, "hist").unwrap();
    assert!(saved > 0);

    // ...and reading it back must see the committed objects.
    let lines = sc.count(&sc.text_file(OUTPUT_BUCKET, "hist/")).unwrap();
    assert!(lines > 0, "read-back after save must see the new objects");

    // With the bucket quiescent again, the re-listing IS reused: the
    // next read of the same prefix hits the scan cache.
    let hits_before = env.metrics().get("scan.list_cache_hits");
    assert_eq!(sc.count(&sc.text_file(OUTPUT_BUCKET, "hist/")).unwrap(), lines);
    assert!(
        env.metrics().get("scan.list_cache_hits") > hits_before,
        "a quiescent prefix is served from the scan cache"
    );
}

#[test]
fn cache_off_is_byte_identical_to_marker_free_runs() {
    // The regression pin for "semantically invisible when off": the
    // default config (capacity 0) with markers everywhere must produce
    // the same report and the same metrics registry as a marker-free
    // lineage in a fresh environment.
    let cfg = modeled_cfg();
    assert_eq!(cfg.flint.cache.capacity_bytes, 0, "off by default");
    let run = |cached: bool| {
        let env = SimEnv::new(cfg.clone());
        generate_taxi_dataset(&env, "trips", cfg.data.trips);
        let sc = FlintContext::new(env.clone());
        sc.prewarm();
        let report = sc.run(&hour_pairs(&sc, cached), Action::Collect).unwrap();
        (format!("{report:?}"), env.metrics().snapshot())
    };
    let (marked, marked_metrics) = run(true);
    let (plain, plain_metrics) = run(false);
    assert_eq!(marked, plain, "cache off must reproduce the marker-free report");
    assert_eq!(marked_metrics, plain_metrics, "and the exact metrics registry");
    assert!(
        marked_metrics.iter().all(|(k, _)| !k.starts_with("cache.")),
        "no cache meters when off: {marked_metrics:?}"
    );
}

#[test]
fn warm_rerun_beats_cold_on_latency_and_gb_seconds() {
    // The A11 gate's unit-level guard: one session, capacity on, the
    // same handles run twice. The cold run pays the build; the warm
    // re-run compiles a truncated plan over the cached cut and must win
    // on BOTH latency and GB-seconds.
    let mut cfg = modeled_cfg();
    cfg.flint.cache.capacity_bytes = 1 << 30;
    let env = SimEnv::new(cfg.clone());
    generate_taxi_dataset(&env, "trips", cfg.data.trips);
    let sc = FlintContext::new(env.clone());
    sc.prewarm();
    let rdd = hour_pairs(&sc, true);

    let gb_s = |r: &flint::exec::QueryReport| {
        r.cost.get(flint::cost::CostCategory::LambdaCompute) / cfg.pricing.lambda_gb_s
    };
    let cold = sc.run(&rdd, Action::Collect).unwrap();
    assert!(env.metrics().get("cache.builds") >= 1);
    let warm = sc.run(&rdd, Action::Collect).unwrap();
    assert!(env.metrics().get("cache.hits") >= 1);
    assert_eq!(format!("{:?}", cold.result), format!("{:?}", warm.result));
    assert!(
        warm.latency_s < cold.latency_s,
        "warm {} must beat cold {} on latency",
        warm.latency_s,
        cold.latency_s
    );
    assert!(
        gb_s(&warm) < gb_s(&cold),
        "warm {} must beat cold {} on GB-seconds",
        gb_s(&warm),
        gb_s(&cold)
    );
}
