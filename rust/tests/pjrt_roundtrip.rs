//! AOT round-trip: the Rust PJRT runtime loads the artifacts produced by
//! `make artifacts` and must agree with the native Rust kernel on every
//! query. Skips (with a notice) when artifacts haven't been built.

use flint::compute::batch::ColumnBatch;
use flint::compute::kernels::{prepare_keys, prepare_values, run_batch_native, HistAccum};
use flint::compute::queries::QueryId;
use flint::data::taxi::generate_csv_object;
use flint::data::weather::WeatherTable;
use flint::runtime::PjrtRuntime;

fn artifacts_dir() -> String {
    std::env::var("FLINT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

fn runtime_or_skip() -> Option<PjrtRuntime> {
    let dir = artifacts_dir();
    if !PjrtRuntime::available(&dir) {
        eprintln!("SKIP: no artifacts in `{dir}` — run `make artifacts` first");
        return None;
    }
    Some(PjrtRuntime::open(&dir).expect("artifacts present but unloadable"))
}

/// Build one padded batch of real generated trips.
fn real_batch(rows: usize, capacity: usize) -> ColumnBatch {
    let csv = generate_csv_object(4242, 17, rows as u64);
    let mut batch = ColumnBatch::with_capacity(capacity);
    for line in csv.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
        if batch.is_full() {
            break;
        }
        assert!(batch.push_line(line));
    }
    batch.pad_to_capacity();
    batch
}

#[test]
fn pjrt_matches_native_on_all_queries() {
    let Some(rt) = runtime_or_skip() else { return };
    let b = rt.batch_rows();
    let batch = real_batch(b - 7, b); // deliberately not full: padding live
    let weather = WeatherTable::generate(4242);

    for q in QueryId::ALL {
        let spec = q.spec();
        let keys = prepare_keys(&spec, &batch, Some(&weather));
        let values = prepare_values(&spec, &batch);

        let mut native = HistAccum::new(spec.buckets);
        run_batch_native(&spec, &batch, &keys, &values, &mut native);

        let mut pjrt = HistAccum::new(spec.buckets);
        rt.run_hist(&spec, &batch, &keys, &values, &mut pjrt)
            .unwrap_or_else(|e| panic!("{q}: {e:#}"));

        assert_eq!(native.rows_seen, pjrt.rows_seen, "{q} rows");
        for k in 0..spec.buckets {
            assert!(
                (native.counts[k] - pjrt.counts[k]).abs() < 1e-3,
                "{q} bucket {k}: native count {} vs pjrt {}",
                native.counts[k],
                pjrt.counts[k]
            );
            assert!(
                (native.sums[k] - pjrt.sums[k]).abs() < 1e-2 * (1.0 + native.sums[k].abs()),
                "{q} bucket {k}: native sum {} vs pjrt {}",
                native.sums[k],
                pjrt.sums[k]
            );
        }
    }
}

#[test]
fn pjrt_concurrent_execution_is_safe() {
    let Some(rt) = runtime_or_skip() else { return };
    let rt = std::sync::Arc::new(rt);
    rt.warmup().unwrap();
    let b = rt.batch_rows();
    let batch = real_batch(b, b);
    let spec = QueryId::Q1.spec();
    let keys = prepare_keys(&spec, &batch, None);
    let values = prepare_values(&spec, &batch);

    let mut expect = HistAccum::new(spec.buckets);
    rt.run_hist(&spec, &batch, &keys, &values, &mut expect).unwrap();

    let handles: Vec<_> = (0..8)
        .map(|_| {
            let rt = std::sync::Arc::clone(&rt);
            let batch = batch.clone();
            let keys = keys.clone();
            let values = values.clone();
            std::thread::spawn(move || {
                let spec = QueryId::Q1.spec();
                let mut acc = HistAccum::new(spec.buckets);
                for _ in 0..4 {
                    rt.run_hist(&spec, &batch, &keys, &values, &mut acc).unwrap();
                }
                acc
            })
        })
        .collect();
    for h in handles {
        let acc = h.join().expect("no panic under concurrency");
        for k in 0..spec.buckets {
            assert!((acc.counts[k] - 4.0 * expect.counts[k]).abs() < 1e-3);
        }
    }
}

#[test]
fn manifest_covers_every_query() {
    let Some(rt) = runtime_or_skip() else { return };
    for q in QueryId::ALL {
        let stem = q.spec().artifact_stem();
        assert!(
            rt.manifest().queries.contains_key(&stem),
            "artifact bundle missing {stem}"
        );
        assert_eq!(rt.manifest().queries[&stem].buckets, q.spec().buckets);
    }
}
