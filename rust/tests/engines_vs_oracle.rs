//! Cross-engine correctness: Flint (SQS shuffle), Flint (S3 shuffle),
//! Spark, and PySpark must all produce the oracle's answer for every
//! benchmark query — and the virtual-time/cost relationships the paper
//! reports must hold in shape.

use flint::compute::oracle;
use flint::compute::queries::QueryId;
use flint::config::{FlintConfig, ShuffleBackend};
use flint::data::{generate_taxi_dataset, Dataset};
use flint::exec::{ClusterEngine, ClusterMode, Engine, FlintEngine};
use flint::services::SimEnv;

const TRIPS: u64 = 30_000;

fn test_config() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    // Enough objects/splits for real parallel structure.
    c.data.object_bytes = 512 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false; // native kernels (PJRT covered in pjrt_roundtrip)
    c
}

fn setup(cfg: FlintConfig) -> (SimEnv, Dataset) {
    let env = SimEnv::new(cfg);
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    (env, ds)
}

/// Paper-shape assertions need S3 streaming to dominate fixed overheads,
/// like the real 215 GB workload — bigger objects/splits, more rows.
fn shape_config() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 16 * 1024 * 1024;
    c.flint.input_split_bytes = 16 * 1024 * 1024;
    c.flint.use_pjrt = false;
    c
}

fn shape_setup() -> (SimEnv, Dataset) {
    let env = SimEnv::new(shape_config());
    let ds = generate_taxi_dataset(&env, "trips", 400_000);
    (env, ds)
}

#[test]
fn all_engines_match_oracle_on_all_queries() {
    let (env, ds) = setup(test_config());
    let flint = FlintEngine::new(env.clone());
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let pyspark = ClusterEngine::new(env.clone(), ClusterMode::PySpark);

    for q in QueryId::ALL {
        let expect = oracle::evaluate(&env, &ds, q);
        for engine in [&flint as &dyn Engine, &spark, &pyspark] {
            let report = engine
                .run_query(q, &ds)
                .unwrap_or_else(|e| panic!("{} {q}: {e:#}", engine.name()));
            assert!(
                report.result.approx_eq(&expect),
                "{} {q}: got {:?}\nwant {:?}",
                engine.name(),
                report.result,
                expect
            );
            assert!(report.latency_s > 0.0);
            assert!(report.cost_usd > 0.0);
        }
    }
}

#[test]
fn flint_s3_shuffle_matches_oracle() {
    let mut cfg = test_config();
    cfg.flint.shuffle_backend = ShuffleBackend::S3;
    let (env, ds) = setup(cfg);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q1, QueryId::Q4, QueryId::Q6] {
        let expect = oracle::evaluate(&env, &ds, q);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(
            report.result.approx_eq(&expect),
            "s3-shuffle {q}: {:?} vs {:?}",
            report.result,
            expect
        );
    }
}

#[test]
fn paper_shape_pyspark_slower_flint_cheaper_than_pyspark() {
    let (env, ds) = shape_setup();
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let pyspark = ClusterEngine::new(env.clone(), ClusterMode::PySpark);

    // Q1: the paper's flagship query.
    let rf = flint.run_query(QueryId::Q1, &ds).unwrap();
    let rs = spark.run_query(QueryId::Q1, &ds).unwrap();
    let rp = pyspark.run_query(QueryId::Q1, &ds).unwrap();

    // Finding 2: PySpark is slower than Scala Spark (pipe overhead).
    assert!(
        rp.latency_s > rs.latency_s,
        "pyspark {:.3}s must exceed spark {:.3}s",
        rp.latency_s,
        rs.latency_s
    );
    // Finding 3: Flint beats PySpark on every query.
    assert!(
        rf.latency_s < rp.latency_s,
        "flint {:.3}s must beat pyspark {:.3}s",
        rf.latency_s,
        rp.latency_s
    );
}

#[test]
fn q0_read_bound_flint_faster_than_spark() {
    // Q0 isolates S3 throughput: Flint's boto-class profile must win
    // (the paper's explanation for Flint beating Spark).
    let (env, ds) = shape_setup();
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let rf = flint.run_query(QueryId::Q0, &ds).unwrap();
    let rs = spark.run_query(QueryId::Q0, &ds).unwrap();
    assert!(
        rf.latency_s < rs.latency_s,
        "flint Q0 {:.3}s vs spark {:.3}s",
        rf.latency_s,
        rs.latency_s
    );
}

#[test]
fn flint_shuffle_queries_use_sqs_and_clean_up() {
    let (env, ds) = setup(test_config());
    let flint = FlintEngine::new(env.clone());
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert!(report.shuffle_msgs > 0, "Q1 must move data through SQS");
    assert_eq!(
        env.sqs().queue_names().len(),
        0,
        "scheduler must delete shuffle queues after the run"
    );
    assert!(env.metrics().get("sqs.send_batch") > 0);
    assert!(env.metrics().get("sqs.delete_batch") > 0, "reducers ack messages");
}

#[test]
fn q0_has_no_shuffle_and_one_stage() {
    let (env, ds) = setup(test_config());
    let flint = FlintEngine::new(env.clone());
    let report = flint.run_query(QueryId::Q0, &ds).unwrap();
    assert_eq!(report.stage_latencies.len(), 1);
    assert_eq!(report.shuffle_msgs, 0);
    assert_eq!(report.result, flint::compute::queries::QueryResult::Count(TRIPS));
}

#[test]
fn cold_vs_warm_latency_difference() {
    let (env, ds) = setup(test_config());
    let flint = FlintEngine::new(env.clone());
    let cold = flint.run_query(QueryId::Q0, &ds).unwrap();
    // Second run finds warm containers.
    let warm = flint.run_query(QueryId::Q0, &ds).unwrap();
    assert!(
        warm.latency_s < cold.latency_s,
        "warm {:.3}s must beat cold {:.3}s",
        warm.latency_s,
        cold.latency_s
    );
    assert!(env.metrics().get("lambda.cold_starts") > 0);
}
