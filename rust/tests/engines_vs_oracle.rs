//! Cross-engine correctness: Flint (SQS shuffle), Flint (S3 shuffle),
//! Spark, and PySpark must all produce the oracle's answer for every
//! benchmark query — and the virtual-time/cost relationships the paper
//! reports must hold in shape.

use flint::compute::oracle;
use flint::compute::queries::QueryId;
use flint::config::{FlintConfig, ShuffleBackend, ShuffleCodec};
use flint::data::{generate_taxi_dataset, Dataset};
use flint::exec::{ClusterEngine, ClusterMode, Engine, FlintEngine};
use flint::plan::{dag, interp, lower, Action, Rdd};
use flint::services::SimEnv;

const TRIPS: u64 = 30_000;

fn test_config() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    // Enough objects/splits for real parallel structure.
    c.data.object_bytes = 512 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false; // native kernels (PJRT covered in pjrt_roundtrip)
    c
}

fn setup(cfg: FlintConfig) -> (SimEnv, Dataset) {
    let env = SimEnv::new(cfg);
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    (env, ds)
}

/// Paper-shape assertions need S3 streaming to dominate fixed overheads,
/// like the real 215 GB workload — bigger objects/splits, more rows.
fn shape_config() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 16 * 1024 * 1024;
    c.flint.input_split_bytes = 16 * 1024 * 1024;
    c.flint.use_pjrt = false;
    c
}

fn shape_setup() -> (SimEnv, Dataset) {
    let env = SimEnv::new(shape_config());
    let ds = generate_taxi_dataset(&env, "trips", 400_000);
    (env, ds)
}

#[test]
fn all_engines_match_oracle_on_all_queries() {
    let (env, ds) = setup(test_config());
    let flint = FlintEngine::new(env.clone());
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let pyspark = ClusterEngine::new(env.clone(), ClusterMode::PySpark);

    for q in QueryId::ALL {
        let expect = oracle::evaluate(&env, &ds, q);
        for engine in [&flint as &dyn Engine, &spark, &pyspark] {
            let report = engine
                .run_query(q, &ds)
                .unwrap_or_else(|e| panic!("{} {q}: {e:#}", engine.name()));
            assert!(
                report.result.approx_eq(&expect),
                "{} {q}: got {:?}\nwant {:?}",
                engine.name(),
                report.result,
                expect
            );
            assert!(report.latency_s > 0.0);
            assert!(report.cost_usd > 0.0);
        }
    }
}

#[test]
fn flint_s3_shuffle_matches_oracle() {
    let mut cfg = test_config();
    cfg.flint.shuffle_backend = ShuffleBackend::S3;
    let (env, ds) = setup(cfg);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q1, QueryId::Q4, QueryId::Q6] {
        let expect = oracle::evaluate(&env, &ds, q);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(
            report.result.approx_eq(&expect),
            "s3-shuffle {q}: {:?} vs {:?}",
            report.result,
            expect
        );
    }
}

#[test]
fn rows_codec_matches_oracle_on_all_backends() {
    // The default wire codec is columnar (covered by every other test
    // here); the legacy record-per-key format stays a first-class codec
    // and must produce identical answers through the SQS, S3, and
    // in-process cluster shuffles — including the tagged join edges.
    let mut cfg = test_config();
    cfg.flint.shuffle_codec = ShuffleCodec::Rows;
    let (env, ds) = setup(cfg.clone());
    let flint_sqs = FlintEngine::new(env.clone());
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let mut s3_cfg = cfg;
    s3_cfg.flint.shuffle_backend = ShuffleBackend::S3;
    let (env_s3, ds_s3) = setup(s3_cfg);
    let flint_s3 = FlintEngine::new(env_s3.clone());
    for q in [QueryId::Q1, QueryId::Q5, QueryId::Q6J] {
        let expect = oracle::evaluate(&env, &ds, q);
        for engine in [&flint_sqs as &dyn Engine, &spark] {
            let r = engine.run_query(q, &ds).unwrap();
            assert!(
                r.result.approx_eq(&expect),
                "rows codec {} {q}: {:?} vs {:?}",
                engine.name(),
                r.result,
                expect
            );
        }
        let expect_s3 = oracle::evaluate(&env_s3, &ds_s3, q);
        let r = flint_s3.run_query(q, &ds_s3).unwrap();
        assert!(
            r.result.approx_eq(&expect_s3),
            "rows codec s3-shuffle {q}: {:?} vs {:?}",
            r.result,
            expect_s3
        );
    }
}

#[test]
fn day_range_pruning_skips_splits_and_preserves_counts() {
    // The generic path's end-to-end pruning story: a leading
    // `filter_day_range` over manifest-backed splits must skip fetching
    // splits whose day stats miss the window, issue fewer S3 GETs, and
    // still count exactly what the unpruned run (and the single-threaded
    // interpreter) counts.
    let run = |prune: bool| {
        let mut cfg = test_config();
        cfg.flint.scan_prune = prune;
        let (env, ds) = setup(cfg);
        let split_bytes = env.config().flint.input_split_bytes;
        let rdd = Rdd::text_file(&ds.bucket, &ds.prefix).filter_day_range(0, 200);
        let plan = lower(&rdd, Action::Count, &|_, _| dag::input_splits(&ds, split_bytes));
        let flint = FlintEngine::new(env.clone());
        let before = env.metrics().get("s3.get");
        let count = flint.run_plan_raw(&plan).unwrap().out.into_count().unwrap();
        let gets = env.metrics().get("s3.get") - before;
        (env, ds, rdd, count, gets)
    };
    let (env_on, _, _, count_on, gets_on) = run(true);
    let (env_off, _, rdd, count_off, gets_off) = run(false);
    assert!(count_on > 0 && count_on < TRIPS, "window must keep a strict subset: {count_on}");
    assert_eq!(count_on, count_off, "pruning changed the count");
    assert!(env_on.metrics().get("scan.splits_pruned") > 0, "stats must prune splits");
    assert_eq!(env_off.metrics().get("scan.splits_pruned"), 0);
    assert!(gets_on < gets_off, "pruned run must fetch less: {gets_on} vs {gets_off} GETs");
    // Anchor both runs to the reference interpreter over the raw lines.
    let lines = |bucket: &str, prefix: &str| -> Vec<String> {
        let mut out = Vec::new();
        for (key, _) in env_off.s3().list(bucket, prefix).unwrap() {
            let (obj, _) = env_off
                .s3()
                .get_object(bucket, &key, env_off.flint_read_profile())
                .unwrap();
            out.extend(String::from_utf8_lossy(obj.bytes()).lines().map(str::to_string));
        }
        out
    };
    assert_eq!(count_on, interp::interpret_count(&rdd, &lines));
}

#[test]
fn paper_shape_pyspark_slower_flint_cheaper_than_pyspark() {
    let (env, ds) = shape_setup();
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let pyspark = ClusterEngine::new(env.clone(), ClusterMode::PySpark);

    // Q1: the paper's flagship query.
    let rf = flint.run_query(QueryId::Q1, &ds).unwrap();
    let rs = spark.run_query(QueryId::Q1, &ds).unwrap();
    let rp = pyspark.run_query(QueryId::Q1, &ds).unwrap();

    // Finding 2: PySpark is slower than Scala Spark (pipe overhead).
    assert!(
        rp.latency_s > rs.latency_s,
        "pyspark {:.3}s must exceed spark {:.3}s",
        rp.latency_s,
        rs.latency_s
    );
    // Finding 3: Flint beats PySpark on every query.
    assert!(
        rf.latency_s < rp.latency_s,
        "flint {:.3}s must beat pyspark {:.3}s",
        rf.latency_s,
        rp.latency_s
    );
}

#[test]
fn q0_read_bound_flint_faster_than_spark() {
    // Q0 isolates S3 throughput: Flint's boto-class profile must win
    // (the paper's explanation for Flint beating Spark).
    let (env, ds) = shape_setup();
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let rf = flint.run_query(QueryId::Q0, &ds).unwrap();
    let rs = spark.run_query(QueryId::Q0, &ds).unwrap();
    assert!(
        rf.latency_s < rs.latency_s,
        "flint Q0 {:.3}s vs spark {:.3}s",
        rf.latency_s,
        rs.latency_s
    );
}

#[test]
fn flint_shuffle_queries_use_sqs_and_clean_up() {
    let (env, ds) = setup(test_config());
    let flint = FlintEngine::new(env.clone());
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert!(report.shuffle_msgs > 0, "Q1 must move data through SQS");
    assert_eq!(
        env.sqs().queue_names().len(),
        0,
        "scheduler must delete shuffle queues after the run"
    );
    assert!(env.metrics().get("sqs.send_batch") > 0);
    assert!(env.metrics().get("sqs.delete_batch") > 0, "reducers ack messages");
}

#[test]
fn q0_has_no_shuffle_and_one_stage() {
    let (env, ds) = setup(test_config());
    let flint = FlintEngine::new(env.clone());
    let report = flint.run_query(QueryId::Q0, &ds).unwrap();
    assert_eq!(report.stage_latencies.len(), 1);
    assert_eq!(report.shuffle_msgs, 0);
    assert_eq!(report.result, flint::compute::queries::QueryResult::Count(TRIPS));
}

#[test]
fn cold_vs_warm_latency_difference() {
    let (env, ds) = setup(test_config());
    let flint = FlintEngine::new(env.clone());
    let cold = flint.run_query(QueryId::Q0, &ds).unwrap();
    // Second run finds warm containers.
    let warm = flint.run_query(QueryId::Q0, &ds).unwrap();
    assert!(
        warm.latency_s < cold.latency_s,
        "warm {:.3}s must beat cold {:.3}s",
        warm.latency_s,
        cold.latency_s
    );
    assert!(env.metrics().get("lambda.cold_starts") > 0);
}
