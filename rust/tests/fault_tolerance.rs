//! Robustness (§VI of the paper): executor failures overcome by retries,
//! SQS at-least-once duplicates overcome by sequence-id dedup, the 300 s
//! duration cap overcome by executor chaining, and the 6 MB payload cap
//! overcome by S3 spill.

use flint::compute::oracle;
use flint::compute::queries::{QueryId, QueryResult};
use flint::config::FlintConfig;
use flint::data::{generate_taxi_dataset, Dataset};
use flint::exec::{Engine, FlintEngine};
use flint::services::SimEnv;

const TRIPS: u64 = 20_000;

fn cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 512 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false;
    c
}

fn setup(c: FlintConfig) -> (SimEnv, Dataset) {
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    (env, ds)
}

#[test]
fn sqs_duplicates_do_not_corrupt_results() {
    let mut c = cfg();
    c.sim.sqs_duplicate_prob = 0.25; // aggressive at-least-once
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q1, QueryId::Q4, QueryId::Q5] {
        let expect = oracle::evaluate(&env, &ds, q);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(
            report.result.approx_eq(&expect),
            "{q} under duplicates: {:?} vs {:?}",
            report.result,
            expect
        );
        assert!(report.duplicates_dropped > 0, "{q}: dedup must have fired");
    }
}

#[test]
fn without_dedup_duplicates_corrupt_counts() {
    // Negative control: disabling §VI dedup under duplicate injection
    // must overcount — proving the dedup test above is load-bearing.
    let mut c = cfg();
    c.sim.sqs_duplicate_prob = 0.5;
    c.flint.dedup_enabled = false;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q4);
    let report = flint.run_query(QueryId::Q4, &ds).unwrap();
    let (QueryResult::Buckets(got), QueryResult::Buckets(want)) = (&report.result, &expect)
    else {
        panic!()
    };
    let got_total: f64 = got.iter().map(|(_, _, c)| c).sum();
    let want_total: f64 = want.iter().map(|(_, _, c)| c).sum();
    assert!(
        got_total > want_total,
        "duplicates must inflate counts without dedup ({got_total} vs {want_total})"
    );
}

#[test]
fn random_lambda_failures_are_retried_to_success() {
    let mut c = cfg();
    c.sim.lambda_failure_prob = 0.10;
    c.flint.max_task_retries = 6;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q0, QueryId::Q1] {
        let expect = oracle::evaluate(&env, &ds, q);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(report.result.approx_eq(&expect), "{q} under failures");
    }
    assert!(
        env.metrics().get("scheduler.task_retries") > 0,
        "failures must actually have occurred"
    );
}

#[test]
fn forced_map_crash_mid_task_is_exactly_once() {
    // Crash a specific map task after it processed its first block; the
    // retry re-sends deterministic (producer, seq) messages and dedup
    // keeps the answer exact.
    let (env, ds) = setup(cfg());
    env.failure().force_task_failure(0, 1, 0); // stage 0, task 1, first attempt
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q4);
    let report = flint.run_query(QueryId::Q4, &ds).unwrap();
    assert_eq!(report.retries, 1);
    assert!(report.result.approx_eq(&expect), "{:?} vs {expect:?}", report.result);
}

#[test]
fn forced_reducer_crash_redelivers_messages() {
    let (env, ds) = setup(cfg());
    env.failure().force_task_failure(1, 0, 0); // first reduce task, first attempt
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert_eq!(report.retries, 1);
    assert!(report.result.approx_eq(&expect));
    assert!(env.metrics().get("sqs.nacked") > 0, "visibility-timeout path exercised");
}

#[test]
fn task_fails_after_max_retries() {
    let mut c = cfg();
    c.flint.max_task_retries = 2;
    let (env, ds) = setup(c);
    for attempt in 0..=2 {
        env.failure().force_task_failure(0, 0, attempt);
    }
    let flint = FlintEngine::new(env.clone());
    let err = flint.run_query(QueryId::Q0, &ds).unwrap_err();
    assert!(format!("{err:#}").contains("failed after"), "{err:#}");
}

#[test]
fn chaining_past_duration_cap_preserves_results() {
    // A tiny duration cap forces map tasks to checkpoint + chain
    // (§III-B); results must be identical and chains visible. Splits are
    // sized so one link's S3 read + work exceeds the budget while the
    // final shuffle flush still fits in a dedicated emit link.
    let mut c = cfg();
    c.data.object_bytes = 2 * 1024 * 1024;
    c.flint.input_split_bytes = 2 * 1024 * 1024;
    c.sim.s3_flint_mbps = 85.0; // chain thresholds tuned to this rate
    c.sim.lambda_time_limit_s = 0.06;
    // Budget (cap - margin = 43 ms) sits *below* one split's modeled S3
    // read (~45.5 ms incl. payload decode), so every task must chain at
    // least once no matter how fast the host's measured compute is; the
    // cap leaves ~14 ms of headroom for one (debug-slow) compute block.
    c.sim.lambda_chain_margin_s = 0.017;
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", 120_000);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q0, QueryId::Q1] {
        let expect = oracle::evaluate(&env, &ds, q);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(report.result.approx_eq(&expect), "{q} chained: {:?}", report.result);
        assert!(report.chains > 0, "{q}: chaining must have fired");
        assert_eq!(report.retries, 0, "{q}: chaining is not failure");
        assert!(
            report.invocations > report.tasks,
            "chained tasks re-invoke ({} invocations / {} tasks)",
            report.invocations,
            report.tasks
        );
    }
}

#[test]
fn chaining_and_duplicates_compose() {
    let mut c = cfg();
    c.data.object_bytes = 2 * 1024 * 1024;
    c.flint.input_split_bytes = 2 * 1024 * 1024;
    c.sim.s3_flint_mbps = 85.0; // chain thresholds tuned to this rate
    c.sim.lambda_time_limit_s = 0.06;
    c.sim.lambda_chain_margin_s = 0.017; // see chaining test above
    c.sim.sqs_duplicate_prob = 0.2;
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", 120_000);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q5);
    let report = flint.run_query(QueryId::Q5, &ds).unwrap();
    assert!(report.result.approx_eq(&expect));
    assert!(report.chains > 0);
}

#[test]
fn oversized_payload_spills_through_s3() {
    let mut c = cfg();
    // Force the spill path: absurdly small payload limit.
    c.sim.lambda_payload_limit_bytes = 400;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert!(report.result.approx_eq(&expect));
    assert!(
        env.metrics().get("scheduler.payload_spills") > 0,
        "payload-split workaround must fire"
    );
}

#[test]
fn duration_cap_without_chaining_margin_fails_then_config_fixes_it() {
    // With chaining margin zero and a cap below a single link's work, the
    // Lambda service kills the invocation (DurationExceeded) and retries
    // can't help — the error must surface, mentioning the cap.
    let mut c = cfg();
    c.sim.lambda_time_limit_s = 0.01; // below one S3 first-byte latency
    c.sim.lambda_chain_margin_s = 0.0;
    c.flint.max_task_retries = 1;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    let err = flint.run_query(QueryId::Q0, &ds).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("duration") || text.contains("failed after"), "{text}");
}
