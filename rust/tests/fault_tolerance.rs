//! Robustness (§VI of the paper): executor failures overcome by retries,
//! SQS at-least-once duplicates overcome by sequence-id dedup, the 300 s
//! duration cap overcome by executor chaining, the 6 MB payload cap
//! overcome by S3 spill — and, with the attempt model, racing duplicate
//! (speculative) attempts overcome by attempt-safe commits + dedup on
//! every shuffle backend.

use flint::compute::oracle;
use flint::compute::queries::{QueryId, QueryResult};
use flint::compute::value::Value;
use flint::config::{FlintConfig, ShuffleBackend};
use flint::data::{generate_taxi_dataset, Dataset, INPUT_BUCKET, OUTPUT_BUCKET};
use flint::exec::{ClusterEngine, ClusterMode, Engine, FlintContext, FlintEngine};
use flint::services::SimEnv;

const TRIPS: u64 = 20_000;

fn cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 512 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false;
    c
}

fn setup(c: FlintConfig) -> (SimEnv, Dataset) {
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", TRIPS);
    (env, ds)
}

#[test]
fn sqs_duplicates_do_not_corrupt_results() {
    let mut c = cfg();
    c.sim.sqs_duplicate_prob = 0.25; // aggressive at-least-once
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q1, QueryId::Q4, QueryId::Q5] {
        let expect = oracle::evaluate(&env, &ds, q);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(
            report.result.approx_eq(&expect),
            "{q} under duplicates: {:?} vs {:?}",
            report.result,
            expect
        );
        assert!(report.duplicates_dropped > 0, "{q}: dedup must have fired");
    }
}

#[test]
fn without_dedup_duplicates_corrupt_counts() {
    // Negative control: disabling §VI dedup under duplicate injection
    // must overcount — proving the dedup test above is load-bearing.
    let mut c = cfg();
    c.sim.sqs_duplicate_prob = 0.5;
    c.flint.dedup_enabled = false;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q4);
    let report = flint.run_query(QueryId::Q4, &ds).unwrap();
    let (QueryResult::Buckets(got), QueryResult::Buckets(want)) = (&report.result, &expect)
    else {
        panic!()
    };
    let got_total: f64 = got.iter().map(|(_, _, c)| c).sum();
    let want_total: f64 = want.iter().map(|(_, _, c)| c).sum();
    assert!(
        got_total > want_total,
        "duplicates must inflate counts without dedup ({got_total} vs {want_total})"
    );
}

#[test]
fn random_lambda_failures_are_retried_to_success() {
    let mut c = cfg();
    c.sim.lambda_failure_prob = 0.10;
    c.flint.max_task_retries = 6;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q0, QueryId::Q1] {
        let expect = oracle::evaluate(&env, &ds, q);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(report.result.approx_eq(&expect), "{q} under failures");
    }
    assert!(
        env.metrics().get("scheduler.task_retries") > 0,
        "failures must actually have occurred"
    );
}

#[test]
fn forced_map_crash_mid_task_is_exactly_once() {
    // Crash a specific map task after it processed its first block; the
    // retry re-sends deterministic (producer, seq) messages and dedup
    // keeps the answer exact.
    let (env, ds) = setup(cfg());
    env.failure().force_task_failure(0, 1, 0); // stage 0, task 1, first attempt
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q4);
    let report = flint.run_query(QueryId::Q4, &ds).unwrap();
    assert_eq!(report.retries, 1);
    assert!(report.result.approx_eq(&expect), "{:?} vs {expect:?}", report.result);
}

#[test]
fn forced_reducer_crash_redelivers_messages() {
    let (env, ds) = setup(cfg());
    env.failure().force_task_failure(1, 0, 0); // first reduce task, first attempt
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert_eq!(report.retries, 1);
    assert!(report.result.approx_eq(&expect));
    assert!(env.metrics().get("sqs.nacked") > 0, "visibility-timeout path exercised");
}

#[test]
fn speculative_map_attempts_race_exactly_once_on_sqs_and_s3() {
    // A forced 8x straggler on a scan task triggers a speculative
    // backup that really re-executes, racing byte-identical shuffle
    // writes against the primary's. On the destructive-read SQS backend
    // the duplicates dedup; on the S3 backend the backup overwrites the
    // same keys idempotently. Either way the answer must be exact.
    for backend in [ShuffleBackend::Sqs, ShuffleBackend::S3] {
        let mut c = cfg();
        c.flint.shuffle_backend = backend;
        c.flint.speculation.enabled = true;
        let (env, ds) = setup(c);
        env.failure().force_straggler(0, 1, 0, 8.0);
        let flint = FlintEngine::new(env.clone());
        let expect = oracle::evaluate(&env, &ds, QueryId::Q4);
        let report = flint.run_query(QueryId::Q4, &ds).unwrap();
        assert!(
            report.speculative_launches >= 1,
            "{backend:?}: tail signal must fire"
        );
        assert!(
            report.result.approx_eq(&expect),
            "{backend:?}: racing attempts corrupted the result: {:?} vs {expect:?}",
            report.result
        );
        assert_eq!(report.retries, 0, "{backend:?}: speculation is not failure");
        if backend == ShuffleBackend::Sqs {
            assert!(
                report.duplicates_dropped > 0,
                "the loser's duplicate messages must be dropped by dedup"
            );
            assert_eq!(env.sqs().queue_names().len(), 0, "leaked queues");
        }
    }
}

#[test]
fn speculative_reducer_backup_races_for_real_on_s3_shuffle() {
    // A straggling *reducer* gets a backup too — on the S3 shuffle
    // backend, where the partition's objects persist until the prefix
    // teardown, so the backup genuinely re-reads the full input,
    // re-aggregates, and emits a duplicate result that first-commit-wins
    // discards. The answer must stay exact.
    let mut c = cfg();
    c.flint.shuffle_backend = ShuffleBackend::S3;
    c.flint.speculation.enabled = true;
    // Half-quorum: a reduce straggler must still be running when the
    // median stabilizes (30 short drain-bound tasks finish in quick
    // waves; at the default 0.75 quantile an 8x straggler can
    // occasionally commit first — cross-checked over 20k mirror trials).
    c.flint.speculation.quantile = 0.5;
    let (env, ds) = setup(c);
    env.failure().force_straggler(1, 0, 0, 8.0); // first reduce task
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert!(report.speculative_launches >= 1, "reducer tail signal must fire");
    assert!(report.result.approx_eq(&expect), "{:?} vs {expect:?}", report.result);
}

/// The save lineage the committer suite runs: trips lines keyed by
/// `len % 7`, counted into `parts` reduce partitions, each reduce task
/// committing one final part file under `bucket/prefix`.
fn save_pipeline(sc: &FlintContext, parts: usize, prefix: &str) -> u64 {
    sc.text_file(INPUT_BUCKET, "trips/")
        .map(|v| {
            let len = v.as_str().map(|s| s.len() as i64).unwrap_or(0);
            Value::pair(Value::I64(len % 7), Value::I64(1))
        })
        .reduce_by_key(parts, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
        .save_as_text_file(OUTPUT_BUCKET, prefix)
        .unwrap()
}

#[test]
fn speculative_save_attempts_race_the_committer_without_tearing_parts() {
    // A straggling reduce task whose output is a *final S3 part file*:
    // on the S3 shuffle backend the task draws a speculative backup, so
    // two byte-identical attempts race `commit_rename` for the same
    // part key. First-commit-wins must leave exactly one whole part per
    // reduce task, sweep every attempt-suffixed temp, and the losing
    // attempt must really have reached (and lost) the commit.
    let run = |straggle: bool, prefix: &str| {
        let mut c = cfg();
        c.flint.shuffle_backend = ShuffleBackend::S3;
        c.flint.speculation.enabled = true;
        c.flint.speculation.quantile = 0.5;
        let (env, ds) = setup(c);
        if straggle {
            env.failure().force_straggler(1, 0, 0, 8.0); // first save task
        }
        let sc = FlintContext::new(env.clone());
        sc.register_manifest(&ds);
        let saved = save_pipeline(&sc, 30, prefix);
        (env, saved)
    };
    let (env, _saved) = run(true, "race-out");
    assert!(
        env.metrics().get("scheduler.speculative_launches") >= 1,
        "the save-stage straggler must draw a backup"
    );
    assert!(
        env.metrics().get("s3.commit_lost") >= 1,
        "the losing attempt must reach the rename and lose it"
    );
    // Exactly one committed part per reduce task, nothing else — in
    // particular no `_tmp/` orphans (they would sort first in the
    // listing) and no attempt-suffixed duplicates.
    let parts = env.s3().list(OUTPUT_BUCKET, "race-out/").unwrap();
    let keys: Vec<String> = parts.iter().map(|(k, _)| k.clone()).collect();
    let want: Vec<String> = (0..30).map(|i| format!("race-out/part-{i:05}")).collect();
    assert_eq!(keys, want, "committed directory must be exactly one part per task");
    // Byte-identical to a race-free control run: the race neither tore
    // nor clobbered any part.
    let (env2, saved2) = run(false, "race-out");
    assert_eq!(saved2, 30, "control: one saved object per reduce task");
    for (key, _) in &parts {
        let (a, _) = env.s3().get_object(OUTPUT_BUCKET, key, env.flint_read_profile()).unwrap();
        let (b, _) = env2.s3().get_object(OUTPUT_BUCKET, key, env2.flint_read_profile()).unwrap();
        assert_eq!(a.bytes(), b.bytes(), "{key}: racing commits changed the part bytes");
    }
}

#[test]
fn crashed_save_attempts_retry_to_a_clean_commit_on_both_backends() {
    // Kill a save task's first attempt mid-task on each shuffle backend:
    // the retry is a fresh attempt with its own temp key, so the commit
    // still lands exactly one part per task and the winner's sweep
    // leaves no orphaned temps behind.
    for backend in [ShuffleBackend::Sqs, ShuffleBackend::S3] {
        let mut c = cfg();
        c.flint.shuffle_backend = backend;
        let (env, ds) = setup(c);
        env.failure().force_task_failure(1, 2, 0); // a save task's first attempt
        let sc = FlintContext::new(env.clone());
        sc.register_manifest(&ds);
        let saved = save_pipeline(&sc, 8, "crash-out");
        assert_eq!(saved, 8, "{backend:?}: one saved object per reduce task");
        assert_eq!(env.metrics().get("scheduler.task_retries"), 1, "{backend:?}");
        let keys: Vec<String> = env
            .s3()
            .list(OUTPUT_BUCKET, "crash-out/")
            .unwrap()
            .into_iter()
            .map(|(k, _)| k)
            .collect();
        let want: Vec<String> = (0..8).map(|i| format!("crash-out/part-{i:05}")).collect();
        assert_eq!(keys, want, "{backend:?}: retry must commit cleanly, with no temps left");
    }
}

#[test]
fn reduce_tasks_sit_speculation_out_on_destructive_read_backends() {
    // On SQS (and memory) the primary's commit acks the partition away,
    // so a backup would drain an empty queue in ~0s — an unmeasurable
    // duration the clocks must not model. The scheduler therefore never
    // speculates shuffle-input tasks on destructive-read backends: a
    // forced reduce straggler draws no backup, and the answer (and
    // queue lifecycle) is unaffected.
    let mut c = cfg();
    c.flint.speculation.enabled = true;
    c.flint.speculation.quantile = 0.5;
    // High multiplier: natural map-task variance (measured compute under
    // test-runner contention) must never draw a backup, so any launch
    // could only come from the 8x reduce straggler — which is excluded.
    c.flint.speculation.multiplier = 3.0;
    let (env, ds) = setup(c);
    env.failure().force_straggler(1, 0, 0, 8.0); // first reduce task
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert_eq!(
        report.speculative_launches, 0,
        "destructive-read reduce tasks must not draw backups"
    );
    assert!(report.result.approx_eq(&expect), "{:?} vs {expect:?}", report.result);
    assert_eq!(env.sqs().queue_names().len(), 0, "leaked queues");
}

#[test]
fn speculation_and_crash_retries_compose_on_memory_backend() {
    // The cluster (memory) backend runs the same attempt table: force a
    // straggler AND a mid-task crash on the same stage, with speculation
    // on — retries, backups, and the visibility-timeout machinery must
    // compose to an exact answer.
    let mut c = cfg();
    c.flint.speculation.enabled = true;
    let (env, ds) = setup(c);
    env.failure().force_straggler(0, 1, 0, 8.0);
    env.failure().force_task_failure(0, 2, 0);
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let expect = oracle::evaluate(&env, &ds, QueryId::Q4);
    let report = spark.run_query(QueryId::Q4, &ds).unwrap();
    assert!(report.result.approx_eq(&expect), "{:?} vs {expect:?}", report.result);
    assert_eq!(report.retries, 1, "the forced crash retried exactly once");
    assert!(
        env.metrics().get("scheduler.speculative_launches") >= 1,
        "the straggler must have drawn a backup"
    );
}

#[test]
fn task_retries_counts_attempts_not_exhausted_failures() {
    // Regression (attempt model): `scheduler.task_retries` counts the
    // relaunches actually made. A task that exhausts a 2-retry budget
    // fails 3 times but only ever relaunched twice — the old counter
    // reported 3, overstating retry rates in RunOutput.
    let mut c = cfg();
    c.flint.max_task_retries = 2;
    let (env, ds) = setup(c);
    for attempt in 0..=2 {
        env.failure().force_task_failure(0, 0, attempt);
    }
    let flint = FlintEngine::new(env.clone());
    let err = flint.run_query(QueryId::Q0, &ds).unwrap_err();
    assert!(format!("{err:#}").contains("failed after"), "{err:#}");
    assert_eq!(
        env.metrics().get("scheduler.task_retries"),
        2,
        "only launched retries count, not the budget-refused failure"
    );
}

#[test]
fn mid_chain_failure_counts_one_retry_not_one_per_segment() {
    // Regression (attempt model): a chain-resume retry is ONE new
    // attempt, however many segments the task chains through before and
    // after the crash.
    let mut c = cfg();
    c.data.object_bytes = 2 * 1024 * 1024;
    c.flint.input_split_bytes = 2 * 1024 * 1024;
    c.sim.s3_flint_mbps = 85.0;
    c.sim.lambda_time_limit_s = 0.06;
    c.sim.lambda_chain_margin_s = 0.017; // see chaining test below
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", 120_000);
    env.failure().force_task_failure(0, 1, 0);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert!(report.result.approx_eq(&expect));
    assert!(report.chains > 0, "chaining must have fired");
    assert_eq!(report.retries, 1, "one crash = one retry, chain segments are not retries");
    assert_eq!(env.metrics().get("scheduler.task_retries"), 1);
}

#[test]
fn injected_stragglers_inflate_billed_time_deterministically() {
    // Random heavy-tail injection: the same seed straggles the same
    // attempts (hash-based draws), the slowdown lands in the Straggler
    // timeline component, and results stay exact.
    let mut c = cfg();
    c.sim.straggler_prob = 0.3;
    c.sim.straggler_factor = 5.0;
    let (env, ds) = setup(c.clone());
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    let r1 = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert!(r1.result.approx_eq(&expect));
    assert!(
        env.metrics().get("sim.straggler_slowdowns") > 0,
        "stragglers must actually have been injected"
    );
    assert!(
        r1.timeline.get(flint::simtime::Component::Straggler) > 0.0,
        "slowdown must be metered in the timeline"
    );
    // Determinism across a fresh environment: same seed, same totals.
    let (env2, ds2) = setup(c);
    let flint2 = FlintEngine::new(env2.clone());
    let r2 = flint2.run_query(QueryId::Q1, &ds2).unwrap();
    assert_eq!(
        env.metrics().get("sim.straggler_slowdowns"),
        env2.metrics().get("sim.straggler_slowdowns"),
        "straggler draws are stateless in (seed, stage, task, attempt)"
    );
    let _ = r2;
}

#[test]
fn task_fails_after_max_retries() {
    let mut c = cfg();
    c.flint.max_task_retries = 2;
    let (env, ds) = setup(c);
    for attempt in 0..=2 {
        env.failure().force_task_failure(0, 0, attempt);
    }
    let flint = FlintEngine::new(env.clone());
    let err = flint.run_query(QueryId::Q0, &ds).unwrap_err();
    assert!(format!("{err:#}").contains("failed after"), "{err:#}");
}

#[test]
fn chaining_past_duration_cap_preserves_results() {
    // A tiny duration cap forces map tasks to checkpoint + chain
    // (§III-B); results must be identical and chains visible. Splits are
    // sized so one link's S3 read + work exceeds the budget while the
    // final shuffle flush still fits in a dedicated emit link.
    let mut c = cfg();
    c.data.object_bytes = 2 * 1024 * 1024;
    c.flint.input_split_bytes = 2 * 1024 * 1024;
    c.sim.s3_flint_mbps = 85.0; // chain thresholds tuned to this rate
    c.sim.lambda_time_limit_s = 0.06;
    // Budget (cap - margin = 43 ms) sits *below* one split's modeled S3
    // read (~45.5 ms incl. payload decode), so every task must chain at
    // least once no matter how fast the host's measured compute is; the
    // cap leaves ~14 ms of headroom for one (debug-slow) compute block.
    c.sim.lambda_chain_margin_s = 0.017;
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", 120_000);
    let flint = FlintEngine::new(env.clone());
    for q in [QueryId::Q0, QueryId::Q1] {
        let expect = oracle::evaluate(&env, &ds, q);
        let report = flint.run_query(q, &ds).unwrap();
        assert!(report.result.approx_eq(&expect), "{q} chained: {:?}", report.result);
        assert!(report.chains > 0, "{q}: chaining must have fired");
        assert_eq!(report.retries, 0, "{q}: chaining is not failure");
        assert!(
            report.invocations > report.tasks,
            "chained tasks re-invoke ({} invocations / {} tasks)",
            report.invocations,
            report.tasks
        );
    }
}

#[test]
fn chaining_and_duplicates_compose() {
    let mut c = cfg();
    c.data.object_bytes = 2 * 1024 * 1024;
    c.flint.input_split_bytes = 2 * 1024 * 1024;
    c.sim.s3_flint_mbps = 85.0; // chain thresholds tuned to this rate
    c.sim.lambda_time_limit_s = 0.06;
    c.sim.lambda_chain_margin_s = 0.017; // see chaining test above
    c.sim.sqs_duplicate_prob = 0.2;
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", 120_000);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q5);
    let report = flint.run_query(QueryId::Q5, &ds).unwrap();
    assert!(report.result.approx_eq(&expect));
    assert!(report.chains > 0);
}

#[test]
fn oversized_payload_spills_through_s3() {
    let mut c = cfg();
    // Force the spill path: absurdly small payload limit.
    c.sim.lambda_payload_limit_bytes = 400;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    let expect = oracle::evaluate(&env, &ds, QueryId::Q1);
    let report = flint.run_query(QueryId::Q1, &ds).unwrap();
    assert!(report.result.approx_eq(&expect));
    assert!(
        env.metrics().get("scheduler.payload_spills") > 0,
        "payload-split workaround must fire"
    );
}

#[test]
fn duration_cap_without_chaining_margin_fails_then_config_fixes_it() {
    // With chaining margin zero and a cap below a single link's work, the
    // Lambda service kills the invocation (DurationExceeded) and retries
    // can't help — the error must surface, mentioning the cap.
    let mut c = cfg();
    c.sim.lambda_time_limit_s = 0.01; // below one S3 first-byte latency
    c.sim.lambda_chain_margin_s = 0.0;
    c.flint.max_task_retries = 1;
    let (env, ds) = setup(c);
    let flint = FlintEngine::new(env.clone());
    let err = flint.run_query(QueryId::Q0, &ds).unwrap_err();
    let text = format!("{err:#}");
    assert!(text.contains("duration") || text.contains("failed after"), "{text}");
}
