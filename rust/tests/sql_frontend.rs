//! End-to-end tests for the SQL frontend: the Table I corpus (plus the
//! forced-shuffle Q6J plan) compiled from SQL text and held to the
//! lineage-interpreter oracle on every shuffle backend and scheduler;
//! the stats-based pruning regression for day windows hiding behind
//! generic predicates; optimizer on/off answer equivalence; a parser
//! fuzz sweep (mutated SQL must always produce a typed `SqlError` with
//! an in-bounds byte offset, never a panic); and the EXPLAIN snapshot.

use flint::compute::queries::QueryId;
use flint::config::{FlintConfig, ShuffleBackend};
use flint::data::{generate_taxi_dataset, Dataset, INPUT_BUCKET};
use flint::exec::driver::{run_plan, ActionOut, RunParams};
use flint::exec::executor::IoMode;
use flint::exec::shuffle::{MemoryShuffle, Transport};
use flint::exec::{ClusterMode, FlintContext, FlintService};
use flint::plan::{interp, Action};
use flint::services::SimEnv;
use flint::simtime::ScheduleMode;
use flint::sql::{self, JoinStrategy};

const TRIPS: u64 = 6_000;

fn cfg() -> FlintConfig {
    let mut c = FlintConfig::for_tests();
    c.data.object_bytes = 256 * 1024;
    c.flint.input_split_bytes = 256 * 1024;
    c.flint.use_pjrt = false;
    c
}

fn setup(c: FlintConfig, trips: u64) -> (SimEnv, Dataset, FlintContext) {
    let env = SimEnv::new(c);
    let ds = generate_taxi_dataset(&env, "trips", trips);
    let sc = FlintContext::new(env.clone());
    sc.register_manifest(&ds);
    (env, ds, sc)
}

/// Interpreter line source over the simulated store — the oracle reads
/// the exact bytes the engine scans.
fn s3_lines(env: &SimEnv) -> impl Fn(&str, &str) -> Vec<String> + '_ {
    move |bucket, prefix| {
        let mut listed = env.s3().list(bucket, prefix).unwrap_or_default();
        listed.sort();
        let mut out = Vec::new();
        for (key, _) in listed {
            if let Ok((obj, _)) = env.s3().get_object(bucket, &key, env.flint_read_profile()) {
                out.extend(String::from_utf8_lossy(obj.bytes()).lines().map(String::from));
            }
        }
        out
    }
}

/// Table I + Q6J as SQL: the engine's shaped rows must equal the
/// interpreter oracle's on the SQS and S3 shuffle backends under both
/// the barrier and pipelined schedulers.
#[test]
fn table1_sql_matches_interpreter_on_all_backends_and_schedulers() {
    for q in QueryId::ALL_WITH_JOINS {
        let text = sql::table1_sql(q);
        for backend in [ShuffleBackend::Sqs, ShuffleBackend::S3] {
            for sched in [ScheduleMode::Barrier, ScheduleMode::Pipelined] {
                let mut c = cfg();
                c.flint.shuffle_backend = backend;
                c.flint.scheduler = sched;
                if q == QueryId::Q6J {
                    c.flint.sql.broadcast_threshold_bytes = 0;
                }
                let (env, _ds, sc) = setup(c, TRIPS);
                let job = sc.sql_job(text).unwrap_or_else(|e| panic!("{q}: {e}"));
                let got = job.collect().unwrap_or_else(|e| panic!("{q}: {e}"));
                let lines = s3_lines(&env);
                let expect = job.shape(interp::interpret(&job.rdd, &lines));
                assert_eq!(got.rows, expect, "{q} on {backend:?}/{sched:?}");
                assert!(!got.rows.is_empty(), "{q} returned no rows");
            }
        }
    }
}

/// The same corpus on the in-memory cluster backend: the Spark-baseline
/// context under the barrier clock, and the identical plan re-run
/// through the driver under the pipelined clock.
#[test]
fn table1_sql_matches_interpreter_on_the_memory_backend() {
    for q in [QueryId::Q1, QueryId::Q4, QueryId::Q5, QueryId::Q6, QueryId::Q6J] {
        let mut c = cfg();
        if q == QueryId::Q6J {
            c.flint.sql.broadcast_threshold_bytes = 0;
        }
        let env = SimEnv::new(c);
        let ds = generate_taxi_dataset(&env, "trips", TRIPS);
        let cluster = FlintContext::cluster(env.clone(), ClusterMode::Spark);
        cluster.register_manifest(&ds);
        let job = cluster.sql_job(sql::table1_sql(q)).unwrap_or_else(|e| panic!("{q}: {e}"));
        let got = job.collect().unwrap_or_else(|e| panic!("{q}: {e}"));
        let lines = s3_lines(&env);
        let expect = job.shape(interp::interpret(&job.rdd, &lines));
        assert_eq!(got.rows, expect, "{q} memory/barrier");

        let plan = cluster.lower(&job.rdd, Action::Collect);
        let params = RunParams {
            mode: IoMode::Spark,
            transport: Transport::Memory(MemoryShuffle::new()),
            slots: 16,
            lambda: false,
            host_parallelism: 4,
            schedule: ScheduleMode::Pipelined,
            bill_idle: true,
            predictor: None,
        };
        let out = run_plan(&env, None, &plan, &params).unwrap();
        let ActionOut::Values(vals) = out.out else { panic!("collect produced {:?}", out.out) };
        assert_eq!(job.shape(vals), expect, "{q} memory/pipelined");
    }
}

/// Satellite regression: a day window does not stop pruning splits just
/// because another predicate precedes it in the WHERE clause — the
/// extracted `DayRange` op commutes past pure filters, so the planner
/// still sees it and skips out-of-window splits.
#[test]
fn sql_day_window_prunes_splits_behind_a_generic_predicate() {
    // Small objects: the generator tiles the 7.5-year day span across
    // many objects, so a narrow window leaves most splits prunable.
    let mut c = cfg();
    c.data.object_bytes = 128 * 1024;
    c.flint.input_split_bytes = 128 * 1024;
    let (env, _ds, sc) = setup(c, 20_000);
    let job = sc
        .sql_job("SELECT COUNT(*) FROM trips WHERE tip_amount > 5 AND day BETWEEN 100 AND 200")
        .unwrap();
    let got = job.collect().unwrap();
    let pruned = env.metrics().get("scan.splits_pruned");
    assert!(pruned > 0, "the day window behind `tip_amount > 5` must still prune splits");
    // Pruning must not change the answer.
    let lines = s3_lines(&env);
    let expect = job.shape(interp::interpret(&job.rdd, &lines));
    assert_eq!(got.rows, expect);
}

/// NDV-from-stats: the planner folds the trips scan's per-object
/// day/month stats (manifest-carried here; HEAD-recovered on the
/// listing path) into the day-domain estimate, so a narrow day window
/// that groups by day plans a span-sized exchange instead of clamping
/// the 2738-day schema domain to `flint.default_shuffle_partitions`.
#[test]
fn stats_tighten_group_by_day_exchange_width() {
    let (env, _ds, sc) = setup(cfg(), TRIPS);
    // No window: the generated data tiles the full timeline, so the
    // stats-refined domain still clamps to the default width.
    let wide = sc.sql_job("SELECT day, COUNT(*) FROM trips GROUP BY day").unwrap();
    assert_eq!(wide.choice.agg_partitions, Some(30), "full-span scan keeps the default width");
    let narrow = sc
        .sql_job("SELECT day, COUNT(*) FROM trips WHERE day BETWEEN 100 AND 110 GROUP BY day")
        .unwrap();
    assert_eq!(narrow.choice.agg_partitions, Some(11), "an 11-day window needs 11 partitions");
    // The tightened exchange must not move the answer.
    let got = narrow.collect().unwrap();
    let lines = s3_lines(&env);
    assert_eq!(got.rows, narrow.shape(interp::interpret(&narrow.rdd, &lines)));

    // Stat-less splits (no manifest, pruning off so the session issues
    // no recovery HEADs) void the bound: back to the schema-wide clamp.
    let mut c = cfg();
    c.flint.scan_prune = false;
    let env2 = SimEnv::new(c);
    let _ds2 = generate_taxi_dataset(&env2, "trips", TRIPS);
    let sc2 = FlintContext::new(env2.clone());
    let narrow2 = sc2
        .sql_job("SELECT day, COUNT(*) FROM trips WHERE day BETWEEN 100 AND 110 GROUP BY day")
        .unwrap();
    assert_eq!(narrow2.choice.agg_partitions, Some(30), "stat-less splits must not tighten");
}

/// The same regression through the raw Rdd API: `filter` then
/// `filter_day_range` — the shape the old `leading_day_range` walk
/// stopped at.
#[test]
fn rdd_day_range_prunes_behind_a_generic_filter() {
    let mut c = cfg();
    c.data.object_bytes = 128 * 1024;
    c.flint.input_split_bytes = 128 * 1024;
    let (env, _ds, sc) = setup(c, 20_000);
    let rdd = sc
        .text_file(INPUT_BUCKET, "trips/")
        .filter(|v| v.as_str().is_some_and(|s| !s.is_empty()))
        .filter_day_range(100, 200);
    let got = rdd.collect().unwrap();
    assert!(
        env.metrics().get("scan.splits_pruned") > 0,
        "filter-then-day-range must still prune"
    );
    let lines = s3_lines(&env);
    assert_eq!(
        {
            let mut g = got;
            g.sort_by(|a, b| a.total_cmp(b));
            g
        },
        interp::interpret(&rdd, &lines)
    );
}

/// `flint.sql.optimizer = off` lowers the analyzed plan as-is; the
/// answer must not move. The forced-shuffle plan (threshold 0) must
/// also agree with the broadcast plan on the join query.
#[test]
fn optimizer_and_join_strategy_do_not_change_answers() {
    for q in [QueryId::Q1, QueryId::Q4, QueryId::Q6] {
        let text = sql::table1_sql(q);
        let mut rows = Vec::new();
        for (optimizer, threshold) in [(true, u64::MAX), (false, u64::MAX), (true, 0)] {
            let mut c = cfg();
            c.flint.sql.optimizer = optimizer;
            c.flint.sql.broadcast_threshold_bytes = threshold;
            let (_env, _ds, sc) = setup(c, TRIPS);
            let job = sc.sql_job(text).unwrap();
            if q == QueryId::Q6 && optimizer {
                let strategy = job.choice.join.as_ref().map(|j| j.strategy);
                let want = if threshold == 0 {
                    JoinStrategy::Shuffle
                } else {
                    JoinStrategy::Broadcast
                };
                assert_eq!(strategy, Some(want), "{q} threshold={threshold}");
            }
            rows.push(job.collect().unwrap().rows);
        }
        assert_eq!(rows[0], rows[1], "{q}: optimizer off changed the answer");
        assert_eq!(rows[0], rows[2], "{q}: the forced shuffle join changed the answer");
    }
}

/// Fuzz: random mutations of the Table I SQL corpus (and raw garbage)
/// must always come back as `Ok` or a typed `SqlError` whose byte
/// offset lies within the input — never a panic, never an out-of-bounds
/// report.
#[test]
fn parser_fuzz_always_returns_typed_in_bounds_errors() {
    let mut state = 0x5eed_cafe_f00d_u64;
    let mut next = move || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        state >> 33
    };
    let corpus: Vec<&str> = QueryId::ALL_WITH_JOINS.iter().map(|q| sql::table1_sql(*q)).collect();
    let pool: &[u8] = b"SELECT*,()'\"`0159.abzWHERE GROUP BY<>=!- \t\nqxJOIN";
    let mut errors = 0usize;
    for i in 0..2_000 {
        let mut bytes: Vec<u8> = if i % 10 == 9 {
            // Raw garbage, no SQL skeleton at all.
            (0..(next() % 64)).map(|_| pool[(next() as usize) % pool.len()]).collect()
        } else {
            corpus[(next() as usize) % corpus.len()].as_bytes().to_vec()
        };
        for _ in 0..=(next() % 3) {
            if bytes.is_empty() {
                break;
            }
            let at = (next() as usize) % bytes.len();
            match next() % 5 {
                0 => {
                    bytes.remove(at);
                }
                1 => bytes.insert(at, pool[(next() as usize) % pool.len()]),
                2 => bytes[at] = pool[(next() as usize) % pool.len()],
                3 => bytes.truncate(at),
                _ => {
                    let b = (next() as usize) % bytes.len().max(1);
                    bytes.swap(at, b.min(bytes.len() - 1));
                }
            }
        }
        let text = String::from_utf8_lossy(&bytes).into_owned();
        match sql::parse::parse(&text) {
            Ok(stmt) => {
                // Parsed shapes must also analyze without panicking.
                if let Err(e) = sql::logical::analyze(&stmt.query) {
                    assert!(e.offset <= text.len(), "analyze offset {} > len {}", e.offset, text.len());
                    errors += 1;
                }
            }
            Err(e) => {
                assert!(
                    e.offset <= text.len(),
                    "parse offset {} > len {} for {text:?}",
                    e.offset,
                    text.len()
                );
                errors += 1;
            }
        }
    }
    assert!(errors > 200, "mutations produced suspiciously few errors ({errors})");
}

/// EXPLAIN snapshot: section order, pushdown/join/aggregate markers,
/// and byte-for-byte stability across recompiles of the same text
/// against an identical environment.
#[test]
fn explain_is_structured_and_deterministic() {
    let text = "EXPLAIN SELECT w.bucket, COUNT(*) FROM trips t \
                JOIN weather w ON t.day = w.day GROUP BY w.bucket ORDER BY w.bucket";
    let (_env, _ds, sc) = setup(cfg(), TRIPS);
    let rendered = sc.sql_explain(text).unwrap();
    let pos = |needle: &str| {
        rendered.find(needle).unwrap_or_else(|| panic!("EXPLAIN lacks {needle:?}:\n{rendered}"))
    };
    let sections =
        [pos("== SQL =="), pos("== Logical Plan =="), pos("== Optimized Plan =="), pos("== Physical ==")];
    assert!(sections.windows(2).all(|w| w[0] < w[1]), "sections out of order:\n{rendered}");
    // The optimizer's fingerprints: a projected scan, a join pick with
    // both cost estimates, and a tuned aggregation width.
    let lower = rendered.to_lowercase();
    assert!(lower.contains("join"), "{rendered}");
    assert!(lower.contains("broadcast"), "{rendered}");
    assert!(lower.contains("cost["), "{rendered}");
    assert!(lower.contains("aggregate"), "{rendered}");
    assert!(rendered.contains("columns=["), "projection pushdown missing:\n{rendered}");
    // `EXPLAIN` through the statement API returns the plan as rows.
    let via_sql = sc.sql(text).unwrap();
    assert_eq!(via_sql.columns, vec!["plan".to_string()]);
    assert!(!via_sql.rows.is_empty());
    // Same text, same session: identical rendering (the EXPLAIN output
    // is part of the CLI surface, so it must be deterministic).
    assert_eq!(rendered, sc.sql_explain(text).unwrap());
    // Same text, fresh identical environment: still identical.
    let (_env2, _ds2, sc2) = setup(cfg(), TRIPS);
    assert_eq!(rendered, sc2.sql_explain(text).unwrap());
}

/// SQL rides the multi-tenant service like any other lineage: admitted,
/// scheduled, billed to the submitting tenant.
#[test]
fn service_submits_sql_and_bills_the_tenant() {
    let env = SimEnv::new(cfg());
    // The service path resolves splits by listing the store (each
    // submission binds a fresh per-tenant session, so out-of-band
    // manifests don't travel with it).
    let _ds = generate_taxi_dataset(&env, "trips", TRIPS);
    let service = FlintService::new(env.clone());
    service.prewarm();
    service.submit_sql("acme", sql::table1_sql(QueryId::Q1)).unwrap();
    let report = service.run().unwrap();
    assert_eq!(report.queries.len(), 1);
    let ledger = report.ledgers.get("acme").expect("tenant ledger");
    assert!(ledger.total_usd() > 0.0, "the SQL query must bill its tenant");
}

/// The config knobs gate real behavior: `optimizer = off` disables
/// projection pushdown (EXPLAIN shows the full-width scan), and the
/// threshold flips the join pick.
#[test]
fn sql_config_knobs_change_plans() {
    let mut c = cfg();
    c.flint.sql.optimizer = false;
    let (_env, _ds, sc) = setup(c, TRIPS);
    let off = sc.sql_explain("EXPLAIN SELECT hour, COUNT(*) FROM trips GROUP BY hour").unwrap();
    assert!(off.contains("columns=[*]"), "optimizer off must scan full width:\n{off}");
    assert!(off.contains("optimizer off"), "{off}");

    let (_env2, _ds2, sc2) = setup(cfg(), TRIPS);
    let on = sc2.sql_explain("EXPLAIN SELECT hour, COUNT(*) FROM trips GROUP BY hour").unwrap();
    assert!(!on.contains("optimizer off"), "{on}");
    assert!(on.contains("columns=[hour]"), "projection pushdown must narrow the scan:\n{on}");
}
